//! Minimal dense f32 tensor + the blocked GEMV/GEMM kernels the native
//! engine is built on.
//!
//! The native LSTM engine, the PJRT marshalling layer and the serving
//! protocol all move `[B, T, D]`-ish dense f32 data; this small row-major
//! container is all they need. It is deliberately not a general ndarray:
//! no broadcasting, no strides — shape + contiguous data + the couple of
//! ops the engine uses, each with debug-mode shape checks.
//!
//! [`gemv_into`] and [`matmul_into`] are the two accumulation kernels
//! behind every native forward pass (`lstm::cell`, `lstm::plan`). Since
//! the SIMD work (DESIGN.md §13) they are thin entry points through the
//! process-wide [`crate::kernel::dispatch`] table: AVX2+FMA on capable
//! x86_64 hosts, NEON on aarch64, and the original scalar kernels
//! ([`gemv_into_scalar`] / [`matmul_into_scalar`], kept as the parity
//! oracle) everywhere else or when scalar is forced.
//!
//! The invariant every implementation MUST uphold: per output element,
//! `matmul_into` performs the exact same float operations in the exact
//! same order as `gemv_into` on that row — so batched and per-row
//! forwards agree bit-for-bit WITHIN the selected ISA (asserted in
//! `rust/tests/batched_plan.rs` and `rust/tests/simd_parity.rs`). The
//! scalar pair additionally blocks K in quads (and `matmul_into_scalar`
//! blocks output rows in quads so one loaded quad of `W` rows feeds four
//! accumulator rows — MobiRNN §3.3's coarser work units applied to the
//! batch dimension); the SIMD pair instead folds K as one sequential
//! fused-multiply-add chain per element, vectorized across the N
//! dimension, so its results differ from scalar within the small
//! documented bound of DESIGN.md §13 (f32 only — int8 is bit-exact).

use std::fmt;

/// `acc[j] += Σ_r v[r] * W[r][j]` over a row-major `[v.len(), acc.len()]`
/// prefix of `w`, via the process-wide kernel table
/// ([`crate::kernel::dispatch`]).
pub fn gemv_into(acc: &mut [f32], w: &[f32], v: &[f32]) {
    (crate::kernel::dispatch().gemv_f32)(acc, w, v)
}

/// `out[m][j] += Σ_r a[m][r] * W[r][j]` — row-major `[m, k] @ [k, n]`
/// accumulated into a row-major `[m, n]` buffer, via the process-wide
/// kernel table ([`crate::kernel::dispatch`]).
///
/// Bit-for-bit equal to `m` independent [`gemv_into`] calls (same ISA,
/// same per-element accumulation order — every implementation's
/// contract).
pub fn matmul_into(out: &mut [f32], a: &[f32], w: &[f32], m: usize, k: usize, n: usize) {
    (crate::kernel::dispatch().matmul_f32)(out, a, w, m, k, n)
}

/// The scalar quad-K blocked GEMV — the parity oracle and universal
/// fallback behind [`gemv_into`].
///
/// Rows of `W` are processed four at a time so the `acc` accumulator is
/// read/written once per quad instead of once per row (≈4× less
/// accumulator traffic; see EXPERIMENTS.md §Perf). The ≤3-row K
/// remainder accumulates unconditionally — it used to skip `v[r] == 0.0`
/// rows, which made the accumulation path (and the sign of zero results)
/// depend on where a zero fell relative to the quad boundary.
pub fn gemv_into_scalar(acc: &mut [f32], w: &[f32], v: &[f32]) {
    let width = acc.len();
    debug_assert!(w.len() >= v.len() * width, "W too small: {} < {}", w.len(), v.len() * width);
    let mut r = 0;
    while r + 4 <= v.len() {
        let (v0, v1, v2, v3) = (v[r], v[r + 1], v[r + 2], v[r + 3]);
        let base = r * width;
        let w0 = &w[base..base + width];
        let w1 = &w[base + width..base + 2 * width];
        let w2 = &w[base + 2 * width..base + 3 * width];
        let w3 = &w[base + 3 * width..base + 4 * width];
        for ((((a, x0), x1), x2), x3) in acc.iter_mut().zip(w0).zip(w1).zip(w2).zip(w3) {
            *a += v0 * x0 + v1 * x1 + v2 * x2 + v3 * x3;
        }
        r += 4;
    }
    while r < v.len() {
        let vr = v[r];
        let base = r * width;
        for (a, x0) in acc.iter_mut().zip(&w[base..base + width]) {
            *a += vr * x0;
        }
        r += 1;
    }
}

/// The scalar quad-M/quad-K blocked GEMM — the parity oracle and
/// universal fallback behind [`matmul_into`].
///
/// This is [`gemv_into_scalar`]'s quad-K blocking generalized to multiple
/// output rows: output rows are ALSO blocked in quads, so each quad of
/// `W` rows is loaded once and feeds four accumulator rows (16
/// multiply-adds per 4 `W` loads instead of 4 per 4). `W` is traversed
/// once per *quad* of batch rows instead of once per row — the
/// weight-traffic amortization that makes the batched plan beat the
/// per-row path. A duo-row block catches 2–3 row tails (half the reuse),
/// then single rows fall back to [`gemv_into_scalar`]. Per output element
/// the accumulation order is identical to [`gemv_into_scalar`], so
/// results are bit-for-bit equal to m independent GEMVs.
pub fn matmul_into_scalar(out: &mut [f32], a: &[f32], w: &[f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(out.len(), m * n, "out shape");
    debug_assert_eq!(a.len(), m * k, "a shape");
    debug_assert!(w.len() >= k * n, "W too small");
    let mut mi = 0;
    while mi + 4 <= m {
        let (o01, o23) = out[mi * n..(mi + 4) * n].split_at_mut(2 * n);
        let (o0, o1) = o01.split_at_mut(n);
        let (o2, o3) = o23.split_at_mut(n);
        let a0 = &a[mi * k..(mi + 1) * k];
        let a1 = &a[(mi + 1) * k..(mi + 2) * k];
        let a2 = &a[(mi + 2) * k..(mi + 3) * k];
        let a3 = &a[(mi + 3) * k..(mi + 4) * k];
        let mut r = 0;
        while r + 4 <= k {
            let base = r * n;
            let w0 = &w[base..base + n];
            let w1 = &w[base + n..base + 2 * n];
            let w2 = &w[base + 2 * n..base + 3 * n];
            let w3 = &w[base + 3 * n..base + 4 * n];
            // 16 input scalars stay in registers across the whole j sweep.
            let (a00, a01, a02, a03) = (a0[r], a0[r + 1], a0[r + 2], a0[r + 3]);
            let (a10, a11, a12, a13) = (a1[r], a1[r + 1], a1[r + 2], a1[r + 3]);
            let (a20, a21, a22, a23) = (a2[r], a2[r + 1], a2[r + 2], a2[r + 3]);
            let (a30, a31, a32, a33) = (a3[r], a3[r + 1], a3[r + 2], a3[r + 3]);
            for j in 0..n {
                let (x0, x1, x2, x3) = (w0[j], w1[j], w2[j], w3[j]);
                o0[j] += a00 * x0 + a01 * x1 + a02 * x2 + a03 * x3;
                o1[j] += a10 * x0 + a11 * x1 + a12 * x2 + a13 * x3;
                o2[j] += a20 * x0 + a21 * x1 + a22 * x2 + a23 * x3;
                o3[j] += a30 * x0 + a31 * x1 + a32 * x2 + a33 * x3;
            }
            r += 4;
        }
        while r < k {
            let base = r * n;
            let wr = &w[base..base + n];
            for (orow, arow) in [(&mut *o0, a0), (&mut *o1, a1), (&mut *o2, a2), (&mut *o3, a3)] {
                let vr = arow[r];
                for (oj, wj) in orow.iter_mut().zip(wr) {
                    *oj += vr * wj;
                }
            }
            r += 1;
        }
        mi += 4;
    }
    // Duo-M block for a 2–3 row tail (and for 2–3 row batches/chunks):
    // half the reuse of the quad block, still 2× better than row-wise.
    if mi + 2 <= m {
        let (o0, o1) = out[mi * n..(mi + 2) * n].split_at_mut(n);
        let a0 = &a[mi * k..(mi + 1) * k];
        let a1 = &a[(mi + 1) * k..(mi + 2) * k];
        let mut r = 0;
        while r + 4 <= k {
            let base = r * n;
            let w0 = &w[base..base + n];
            let w1 = &w[base + n..base + 2 * n];
            let w2 = &w[base + 2 * n..base + 3 * n];
            let w3 = &w[base + 3 * n..base + 4 * n];
            let (a00, a01, a02, a03) = (a0[r], a0[r + 1], a0[r + 2], a0[r + 3]);
            let (a10, a11, a12, a13) = (a1[r], a1[r + 1], a1[r + 2], a1[r + 3]);
            for j in 0..n {
                let (x0, x1, x2, x3) = (w0[j], w1[j], w2[j], w3[j]);
                o0[j] += a00 * x0 + a01 * x1 + a02 * x2 + a03 * x3;
                o1[j] += a10 * x0 + a11 * x1 + a12 * x2 + a13 * x3;
            }
            r += 4;
        }
        while r < k {
            let base = r * n;
            let wr = &w[base..base + n];
            for (orow, arow) in [(&mut *o0, a0), (&mut *o1, a1)] {
                let vr = arow[r];
                for (oj, wj) in orow.iter_mut().zip(wr) {
                    *oj += vr * wj;
                }
            }
            r += 1;
        }
        mi += 2;
    }
    while mi < m {
        gemv_into_scalar(&mut out[mi * n..(mi + 1) * n], w, &a[mi * k..(mi + 1) * k]);
        mi += 1;
    }
}

/// AVX2+FMA f32 kernels, installed into the dispatch table by
/// `crate::kernel` after runtime detection of `avx2` + `fma`.
///
/// Layout: M-blocks of 4/2/1 output rows (the scalar kernel's blocking,
/// for the same weight-row reuse), each j-vectorized 8 lanes wide. The
/// K dimension folds as ONE sequential fused-multiply-add chain per
/// output element — vector lanes via `_mm256_fmadd_ps`, the `n % 8`
/// scalar tail via `f32::mul_add` (the same fused op) — so every M-block
/// path performs the identical per-element chain and `matmul_into` stays
/// bit-for-bit equal to m independent `gemv_into` calls within this ISA.
/// Versus scalar (which contracts nothing and groups K in quads) results
/// differ within the DESIGN.md §13 bound.
#[cfg(target_arch = "x86_64")]
pub(crate) mod simd {
    use std::arch::x86_64::*;

    pub(crate) fn matmul_into_avx2(
        out: &mut [f32],
        a: &[f32],
        w: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        debug_assert_eq!(out.len(), m * n, "out shape");
        debug_assert_eq!(a.len(), m * k, "a shape");
        debug_assert!(w.len() >= k * n, "W too small");
        // SAFETY: the dispatch table installs this entry only after
        // `is_x86_feature_detected!("avx2")` and `("fma")` both held;
        // the shape asserts above bound every pointer offset used inside.
        unsafe { matmul_avx2(out.as_mut_ptr(), a.as_ptr(), w.as_ptr(), m, k, n) }
    }

    /// GEMV is the m = 1 row of the same kernel — parity by construction.
    pub(crate) fn gemv_into_avx2(acc: &mut [f32], w: &[f32], v: &[f32]) {
        let (k, n) = (v.len(), acc.len());
        debug_assert!(w.len() >= k * n, "W too small: {} < {}", w.len(), k * n);
        // SAFETY: as in `matmul_into_avx2`, with m = 1.
        unsafe { matmul_avx2(acc.as_mut_ptr(), v.as_ptr(), w.as_ptr(), 1, k, n) }
    }

    /// # Safety
    /// Requires AVX2+FMA; `out`/`a`/`w` must be valid for `m*n` / `m*k` /
    /// `k*n` f32 reads (writes for `out`).
    #[target_feature(enable = "avx2,fma")]
    unsafe fn matmul_avx2(out: *mut f32, a: *const f32, w: *const f32, m: usize, k: usize, n: usize) {
        unsafe {
            let mut mi = 0;
            while mi + 4 <= m {
                rows4_avx2(out.add(mi * n), a.add(mi * k), w, k, n);
                mi += 4;
            }
            if mi + 2 <= m {
                rows2_avx2(out.add(mi * n), a.add(mi * k), w, k, n);
                mi += 2;
            }
            while mi < m {
                row1_avx2(out.add(mi * n), a.add(mi * k), w, k, n);
                mi += 1;
            }
        }
    }

    /// # Safety
    /// Requires AVX2+FMA; 4 output rows at `o`, 4 input rows at `a`.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn rows4_avx2(o: *mut f32, a: *const f32, w: *const f32, k: usize, n: usize) {
        unsafe {
            let (o0, o1, o2, o3) = (o, o.add(n), o.add(2 * n), o.add(3 * n));
            let (a0, a1, a2, a3) = (a, a.add(k), a.add(2 * k), a.add(3 * k));
            let mut j = 0;
            while j + 8 <= n {
                let mut s0 = _mm256_loadu_ps(o0.add(j));
                let mut s1 = _mm256_loadu_ps(o1.add(j));
                let mut s2 = _mm256_loadu_ps(o2.add(j));
                let mut s3 = _mm256_loadu_ps(o3.add(j));
                for r in 0..k {
                    let wv = _mm256_loadu_ps(w.add(r * n + j));
                    s0 = _mm256_fmadd_ps(_mm256_set1_ps(*a0.add(r)), wv, s0);
                    s1 = _mm256_fmadd_ps(_mm256_set1_ps(*a1.add(r)), wv, s1);
                    s2 = _mm256_fmadd_ps(_mm256_set1_ps(*a2.add(r)), wv, s2);
                    s3 = _mm256_fmadd_ps(_mm256_set1_ps(*a3.add(r)), wv, s3);
                }
                _mm256_storeu_ps(o0.add(j), s0);
                _mm256_storeu_ps(o1.add(j), s1);
                _mm256_storeu_ps(o2.add(j), s2);
                _mm256_storeu_ps(o3.add(j), s3);
                j += 8;
            }
            while j < n {
                // n % 8 tail: same fused chain, one lane at a time.
                let (mut s0, mut s1) = (*o0.add(j), *o1.add(j));
                let (mut s2, mut s3) = (*o2.add(j), *o3.add(j));
                for r in 0..k {
                    let wv = *w.add(r * n + j);
                    s0 = (*a0.add(r)).mul_add(wv, s0);
                    s1 = (*a1.add(r)).mul_add(wv, s1);
                    s2 = (*a2.add(r)).mul_add(wv, s2);
                    s3 = (*a3.add(r)).mul_add(wv, s3);
                }
                *o0.add(j) = s0;
                *o1.add(j) = s1;
                *o2.add(j) = s2;
                *o3.add(j) = s3;
                j += 1;
            }
        }
    }

    /// # Safety
    /// Requires AVX2+FMA; 2 output rows at `o`, 2 input rows at `a`.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn rows2_avx2(o: *mut f32, a: *const f32, w: *const f32, k: usize, n: usize) {
        unsafe {
            let (o0, o1) = (o, o.add(n));
            let (a0, a1) = (a, a.add(k));
            let mut j = 0;
            while j + 8 <= n {
                let mut s0 = _mm256_loadu_ps(o0.add(j));
                let mut s1 = _mm256_loadu_ps(o1.add(j));
                for r in 0..k {
                    let wv = _mm256_loadu_ps(w.add(r * n + j));
                    s0 = _mm256_fmadd_ps(_mm256_set1_ps(*a0.add(r)), wv, s0);
                    s1 = _mm256_fmadd_ps(_mm256_set1_ps(*a1.add(r)), wv, s1);
                }
                _mm256_storeu_ps(o0.add(j), s0);
                _mm256_storeu_ps(o1.add(j), s1);
                j += 8;
            }
            while j < n {
                let (mut s0, mut s1) = (*o0.add(j), *o1.add(j));
                for r in 0..k {
                    let wv = *w.add(r * n + j);
                    s0 = (*a0.add(r)).mul_add(wv, s0);
                    s1 = (*a1.add(r)).mul_add(wv, s1);
                }
                *o0.add(j) = s0;
                *o1.add(j) = s1;
                j += 1;
            }
        }
    }

    /// # Safety
    /// Requires AVX2+FMA; 1 output row at `o`, 1 input row at `a`.
    #[target_feature(enable = "avx2,fma")]
    unsafe fn row1_avx2(o: *mut f32, a: *const f32, w: *const f32, k: usize, n: usize) {
        unsafe {
            let mut j = 0;
            while j + 8 <= n {
                let mut s0 = _mm256_loadu_ps(o.add(j));
                for r in 0..k {
                    let wv = _mm256_loadu_ps(w.add(r * n + j));
                    s0 = _mm256_fmadd_ps(_mm256_set1_ps(*a.add(r)), wv, s0);
                }
                _mm256_storeu_ps(o.add(j), s0);
                j += 8;
            }
            while j < n {
                let mut s0 = *o.add(j);
                for r in 0..k {
                    s0 = (*a.add(r)).mul_add(*w.add(r * n + j), s0);
                }
                *o.add(j) = s0;
                j += 1;
            }
        }
    }
}

/// NEON f32 kernels (aarch64 baseline) — the AVX2 module's structure at
/// 4 lanes: M-blocks of 4/2/1 rows, per-element K folded as one
/// sequential fused chain (`vfmaq_n_f32` lanes, `f32::mul_add` tail), so
/// the matmul ≡ m × gemv bitwise invariant holds within this ISA too.
#[cfg(target_arch = "aarch64")]
pub(crate) mod simd {
    use std::arch::aarch64::*;

    pub(crate) fn matmul_into_neon(
        out: &mut [f32],
        a: &[f32],
        w: &[f32],
        m: usize,
        k: usize,
        n: usize,
    ) {
        debug_assert_eq!(out.len(), m * n, "out shape");
        debug_assert_eq!(a.len(), m * k, "a shape");
        debug_assert!(w.len() >= k * n, "W too small");
        // SAFETY: NEON is architecturally guaranteed on aarch64; the
        // shape asserts bound every pointer offset used inside.
        unsafe { matmul_neon(out.as_mut_ptr(), a.as_ptr(), w.as_ptr(), m, k, n) }
    }

    /// GEMV is the m = 1 row of the same kernel — parity by construction.
    pub(crate) fn gemv_into_neon(acc: &mut [f32], w: &[f32], v: &[f32]) {
        let (k, n) = (v.len(), acc.len());
        debug_assert!(w.len() >= k * n, "W too small: {} < {}", w.len(), k * n);
        // SAFETY: as in `matmul_into_neon`, with m = 1.
        unsafe { matmul_neon(acc.as_mut_ptr(), v.as_ptr(), w.as_ptr(), 1, k, n) }
    }

    /// # Safety
    /// `out`/`a`/`w` must be valid for `m*n` / `m*k` / `k*n` f32 reads
    /// (writes for `out`).
    #[target_feature(enable = "neon")]
    unsafe fn matmul_neon(out: *mut f32, a: *const f32, w: *const f32, m: usize, k: usize, n: usize) {
        unsafe {
            let mut mi = 0;
            while mi + 2 <= m {
                rows2_neon(out.add(mi * n), a.add(mi * k), w, k, n);
                mi += 2;
            }
            while mi < m {
                row1_neon(out.add(mi * n), a.add(mi * k), w, k, n);
                mi += 1;
            }
        }
    }

    /// # Safety
    /// 2 output rows at `o`, 2 input rows at `a`.
    #[target_feature(enable = "neon")]
    unsafe fn rows2_neon(o: *mut f32, a: *const f32, w: *const f32, k: usize, n: usize) {
        unsafe {
            let (o0, o1) = (o, o.add(n));
            let (a0, a1) = (a, a.add(k));
            let mut j = 0;
            while j + 4 <= n {
                let mut s0 = vld1q_f32(o0.add(j));
                let mut s1 = vld1q_f32(o1.add(j));
                for r in 0..k {
                    let wv = vld1q_f32(w.add(r * n + j));
                    s0 = vfmaq_n_f32(s0, wv, *a0.add(r));
                    s1 = vfmaq_n_f32(s1, wv, *a1.add(r));
                }
                vst1q_f32(o0.add(j), s0);
                vst1q_f32(o1.add(j), s1);
                j += 4;
            }
            while j < n {
                let (mut s0, mut s1) = (*o0.add(j), *o1.add(j));
                for r in 0..k {
                    let wv = *w.add(r * n + j);
                    s0 = (*a0.add(r)).mul_add(wv, s0);
                    s1 = (*a1.add(r)).mul_add(wv, s1);
                }
                *o0.add(j) = s0;
                *o1.add(j) = s1;
                j += 1;
            }
        }
    }

    /// # Safety
    /// 1 output row at `o`, 1 input row at `a`.
    #[target_feature(enable = "neon")]
    unsafe fn row1_neon(o: *mut f32, a: *const f32, w: *const f32, k: usize, n: usize) {
        unsafe {
            let mut j = 0;
            while j + 4 <= n {
                let mut s0 = vld1q_f32(o.add(j));
                for r in 0..k {
                    s0 = vfmaq_n_f32(s0, vld1q_f32(w.add(r * n + j)), *a.add(r));
                }
                vst1q_f32(o.add(j), s0);
                j += 4;
            }
            while j < n {
                let mut s0 = *o.add(j);
                for r in 0..k {
                    s0 = (*a.add(r)).mul_add(*w.add(r * n + j), s0);
                }
                *o.add(j) = s0;
                j += 1;
            }
        }
    }
}

/// Index of the "first finite max" of a slice: the first occurrence of
/// the largest *finite* value. Non-finite entries (NaN, ±inf) are
/// skipped; a slice with no finite value at all maps to 0. This is the
/// crate-wide argmax rule — total, panic-free, and deterministic on NaN
/// logits (which `partial_cmp().unwrap()` was not). +inf is excluded
/// deliberately: any non-finite logit signals numerical breakage
/// upstream, and the rule prefers a defined answer drawn from the
/// values that are still meaningful over amplifying the breakage.
pub fn argmax_slice(row: &[f32]) -> usize {
    let mut best: Option<(usize, f32)> = None;
    for (j, &v) in row.iter().enumerate() {
        if v.is_finite() && best.is_none_or(|(_, bv)| v > bv) {
            best = Some((j, v));
        }
    }
    best.map_or(0, |(j, _)| j)
}

/// Row-major dense f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    /// Build from shape and data; panics if sizes disagree.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} vs {} elems", data.len());
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {:?} -> {shape:?}", self.shape);
        self.shape = shape;
        self
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.ndim(), 2);
        let cols = self.shape[1];
        &self.data[i * cols..(i + 1) * cols]
    }

    /// Slice `[i, :, :]` of a 3-D tensor.
    pub fn slab(&self, i: usize) -> &[f32] {
        debug_assert_eq!(self.ndim(), 3);
        let n = self.shape[1] * self.shape[2];
        &self.data[i * n..(i + 1) * n]
    }

    /// Index of the max element per row of a 2-D tensor (argmax, axis=1),
    /// under the [`argmax_slice`] "first finite max" rule: NaN/±inf
    /// entries are skipped, ties take the first index, and an all-
    /// non-finite row maps to 0.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.ndim(), 2);
        (0..self.shape[0]).map(|i| argmax_slice(self.row(i))).collect()
    }

    /// Max |a - b| over all elements; shapes must match.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Approximate equality within atol + rtol*|b| per element.
    pub fn allclose(&self, other: &Tensor, rtol: f32, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_size() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic]
    fn new_rejects_mismatch() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn zeros_and_reshape() {
        let t = Tensor::zeros(vec![4, 2]).reshape(vec![2, 4]);
        assert_eq!(t.shape(), &[2, 4]);
        assert!(t.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic]
    fn reshape_rejects_bad_count() {
        Tensor::zeros(vec![4]).reshape(vec![5]);
    }

    #[test]
    fn row_and_slab() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        let t3 = Tensor::new(vec![2, 2, 2], (0..8).map(|v| v as f32).collect());
        assert_eq!(t3.slab(1), &[4., 5., 6., 7.]);
    }

    #[test]
    fn argmax_rows_ties_take_first() {
        let t = Tensor::new(vec![2, 3], vec![0.0, 5.0, 5.0, 7.0, 1.0, 2.0]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn argmax_first_finite_max_rule() {
        // NaN anywhere (including position 0) is skipped, not propagated.
        assert_eq!(argmax_slice(&[f32::NAN, 1.0, 2.0]), 2);
        assert_eq!(argmax_slice(&[1.0, f32::NAN, 0.5]), 0);
        // ±inf is not finite: the largest FINITE value wins.
        assert_eq!(argmax_slice(&[f32::INFINITY, 3.0, f32::NEG_INFINITY]), 1);
        // No finite value at all -> 0 (a defined answer, never a panic).
        assert_eq!(argmax_slice(&[f32::NAN, f32::NAN]), 0);
        assert_eq!(argmax_slice(&[f32::INFINITY, f32::NAN]), 0);
        assert_eq!(argmax_slice(&[]), 0);
        // Ties still take the first occurrence.
        assert_eq!(argmax_slice(&[2.0, f32::NAN, 2.0]), 0);
        let t = Tensor::new(vec![2, 2], vec![f32::NAN, 4.0, f32::NAN, f32::NAN]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    /// Naive triple-loop reference for the GEMM kernels.
    fn matmul_naive(a: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for mi in 0..m {
            for r in 0..k {
                for j in 0..n {
                    out[mi * n + j] += a[mi * k + r] * w[r * n + j];
                }
            }
        }
        out
    }

    #[test]
    fn gemv_into_matches_naive() {
        let mut rng = crate::util::Rng::new(31);
        for &(k, n) in &[(1usize, 1usize), (4, 8), (9, 128), (17, 5), (64, 128)] {
            let v: Vec<f32> = (0..k).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mut acc = vec![0.0f32; n];
            gemv_into(&mut acc, &w, &v);
            let expected = matmul_naive(&v, &w, 1, k, n);
            for (a, e) in acc.iter().zip(&expected) {
                assert!((a - e).abs() < 1e-4, "k={k} n={n}: {a} vs {e}");
            }
        }
    }

    #[test]
    fn matmul_into_matches_naive_and_accumulates() {
        let mut rng = crate::util::Rng::new(32);
        for &(m, k, n) in &[
            (1usize, 9usize, 128usize),
            (2, 3, 4),
            (4, 32, 128),
            (5, 7, 6),
            (8, 41, 128),
            (11, 4, 9),
        ] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let bias = rng.uniform(-0.5, 0.5);
            let mut out = vec![bias; m * n];
            matmul_into(&mut out, &a, &w, m, k, n);
            let expected = matmul_naive(&a, &w, m, k, n);
            for (o, e) in out.iter().zip(&expected) {
                assert!((o - (e + bias)).abs() < 1e-3, "m={m} k={k} n={n}: {o} vs {}", e + bias);
            }
        }
    }

    #[test]
    fn matmul_into_bitwise_equals_row_gemvs() {
        // Every implementation (scalar, AVX2, NEON) performs the same
        // per-element float ops in the same order as m independent GEMVs
        // — the invariant the batched-vs-per-window parity test relies
        // on. Runs against whatever the dispatch table selected, so the
        // scalar-forced CI lane covers the oracle and a plain run covers
        // the SIMD path.
        let mut rng = crate::util::Rng::new(33);
        // m values cover every block mix: gemv only (1), duo (2), duo+gemv
        // (3), quad (8), quad+duo (6), quad+gemv (9), quad+duo+gemv (7).
        for &(m, k, n) in &[
            (1usize, 9usize, 16usize),
            (2, 9, 12),
            (3, 9, 16),
            (8, 41, 128),
            (6, 64, 128),
            (9, 5, 7),
            (7, 13, 20),
        ] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mut out = vec![0.25f32; m * n];
            matmul_into(&mut out, &a, &w, m, k, n);
            for mi in 0..m {
                let mut row = vec![0.25f32; n];
                gemv_into(&mut row, &w, &a[mi * k..(mi + 1) * k]);
                assert_eq!(&out[mi * n..(mi + 1) * n], &row[..], "row {mi} m={m} k={k} n={n}");
            }
        }
    }

    #[test]
    fn scalar_k_remainder_is_unconditional() {
        // Regression: the scalar K-remainder used to skip `v[r] == 0.0`
        // rows while the quad body did not, so an all-zero dot product
        // flushed a -0.0 accumulator to +0.0 when k >= 4 (quad body adds
        // 0.0) but left it -0.0 when the zeros fell in the remainder.
        // The remainder now accumulates unconditionally: same path, same
        // bits, for every k mod 4.
        for k in 1..=7usize {
            let n = 5;
            let w: Vec<f32> = (0..k * n).map(|i| i as f32 + 1.0).collect();
            let v = vec![0.0f32; k];
            let mut acc = vec![-0.0f32; n];
            gemv_into_scalar(&mut acc, &w, &v);
            for (j, a) in acc.iter().enumerate() {
                assert_eq!(*a, 0.0, "k={k} j={j}");
                assert!(a.is_sign_positive(), "k={k} j={j}: -0.0 leaked through the remainder");
            }
        }
        // Zeros straddling the quad boundary (last quad lane + both
        // remainder lanes zero): every matmul M-block's remainder must
        // take the same accumulation path as gemv's.
        let (k, n) = (6usize, 9usize);
        let w: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.37).sin()).collect();
        for m in [2usize, 4, 5, 7] {
            let mut a = vec![0.31f32; m * k];
            for row in a.chunks_exact_mut(k) {
                row[3] = 0.0;
                row[4] = 0.0;
                row[5] = 0.0;
            }
            let mut out = vec![-0.0f32; m * n];
            matmul_into_scalar(&mut out, &a, &w, m, k, n);
            for mi in 0..m {
                let mut row = vec![-0.0f32; n];
                gemv_into_scalar(&mut row, &w, &a[mi * k..(mi + 1) * k]);
                assert_eq!(&out[mi * n..(mi + 1) * n], &row[..], "m={m} row {mi}");
            }
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_matches_scalar_when_available() {
        if !(std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma"))
        {
            return;
        }
        // Direct unit check of the AVX2 entry points against the scalar
        // oracle (the full M/K/N sweep lives in tests/simd_parity.rs).
        let mut rng = crate::util::Rng::new(34);
        for &(m, k, n) in &[(1usize, 5usize, 9usize), (4, 32, 128), (7, 33, 17)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
            let mut simd_out = vec![0.5f32; m * n];
            let mut scalar_out = vec![0.5f32; m * n];
            simd::matmul_into_avx2(&mut simd_out, &a, &w, m, k, n);
            matmul_into_scalar(&mut scalar_out, &a, &w, m, k, n);
            for (s, o) in simd_out.iter().zip(&scalar_out) {
                assert!((s - o).abs() <= 2e-4, "m={m} k={k} n={n}: {s} vs {o}");
            }
        }
    }
}
