//! Bench E3 (paper Fig 3): CUDA-style fine offload vs single-thread CPU
//! across the complexity sweep. Prints the figure, times the full-sweep
//! regeneration.

use mobirnn::bench::bench_auto;
use mobirnn::figures;
use mobirnn::simulator::DeviceProfile;

fn main() {
    let n5 = DeviceProfile::nexus5();
    figures::print_fig3(&figures::fig3(&n5));
    println!();
    bench_auto("fig3/regenerate_full_sweep", 50.0, || {
        std::hint::black_box(figures::fig3(&n5));
    });
}
