//! Bench E4 (paper Fig 4): MobiRNN GPU vs CPU on both phones — the
//! headline 3.93x/2.83x. Prints the figure, then times BOTH the
//! simulated path and the REAL serving numerics (PJRT execute of the
//! trained artifact at batch 1 and 8) so the host-side cost of an
//! "offloaded" inference is tracked per commit.

use mobirnn::bench::bench_auto;
use mobirnn::config::Manifest;
use mobirnn::figures;
use mobirnn::runtime::Runtime;
use mobirnn::tensor::Tensor;

fn main() {
    figures::print_fig4(&figures::fig4());
    println!();
    bench_auto("fig4/regenerate", 50.0, || {
        std::hint::black_box(figures::fig4());
    });

    // Real hot path, if artifacts exist.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("(artifacts not built; skipping PJRT benches)");
        return;
    }
    let man = Manifest::load(dir).unwrap();
    let rt = Runtime::start(&man).unwrap();
    for batch in [1usize, 8] {
        let v = man.variant(&format!("lstm_L2_H32_B{batch}")).unwrap();
        rt.preload(&v.name).unwrap();
        let n = batch * v.seq_len * v.input_dim;
        let x = Tensor::new(
            vec![batch, v.seq_len, v.input_dim],
            (0..n).map(|i| (i % 13) as f32 / 13.0).collect(),
        );
        bench_auto(&format!("fig4/pjrt_execute_b{batch}"), 100.0, || {
            std::hint::black_box(rt.execute(&v.name, x.clone()).unwrap());
        });
    }
}
