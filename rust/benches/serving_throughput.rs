//! §Serving end-to-end throughput bench: N concurrent TCP clients
//! driving the full stack — server, scheduler, engine pools — and
//! emitting machine-readable `BENCH_serving.json` (throughput, p50/p99
//! wall latency, shed rate). Artifact-free: the engines are the native
//! CPU paths over the shared random-weight fixture, so the bench runs
//! on every host.
//!
//! Two scenarios frame the pipelined-dispatch change (DESIGN.md §9):
//!
//! - `single_pool` — every request pinned to one engine, so batches
//!   serialize through one worker: the old single-thread router's
//!   behavior, measured on the new code.
//! - `dual_pool`  — requests alternate between the single- and
//!   multi-thread CPU pools, so batches overlap in time: the win the
//!   scheduler/pool split exists to unlock.
//!
//! A third scenario, `quant_pool`, pins every request to the int8
//! quantized engine (DESIGN.md §10) so the quantize → integer GEMM →
//! requantize serving path is driven end to end over TCP — in `--smoke`
//! mode this is the CI gate that keeps the quant engine wired in.
//!
//! A fourth scenario, `streaming` (DESIGN.md §11), has each client open
//! a stateful session and push one frame per `classify_stream` call —
//! the per-step path whose point is NOT re-running the whole window per
//! frame: reported p50/p99 are per-STEP latencies, directly comparable
//! to the per-window numbers of the other scenarios.
//!
//! A `chaos` scenario (DESIGN.md §15) injects a seeded fault plan —
//! 20% failures plus latency spikes on the primary pool — under
//! per-request deadlines: every request must resolve (success or a
//! typed error), successful p99 must respect deadline + watchdog
//! grace, and the in-flight gauges must read zero afterwards (no
//! watchdog leak). In `--smoke` mode those are hard CI assertions.
//!
//! A fifth scenario, `binary_vs_json` (DESIGN.md §12), measures the
//! wire subsystem: the decode cost of one classify request as a JSON
//! line vs a binary frame, and end-to-end throughput over the
//! event-driven server on both transports while ~1k idle connections
//! stay multiplexed on two fixed I/O threads — in `--smoke` mode this
//! asserts the 5× decode win and the 1k-connection capacity.
//!
//! ```bash
//! cargo bench --bench serving_throughput              # full run
//! cargo bench --bench serving_throughput -- --smoke   # CI: tiny N,
//! #   asserts completion (a deadlock here hangs CI), ignores timings
//! ```

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use mobirnn::bench::random_model;
use mobirnn::config::ModelShape;
use mobirnn::coordinator::{
    CpuMultiEngine, CpuQuantEngine, CpuSingleEngine, OffloadPolicy, Router,
};
use mobirnn::faults::FaultPlan;
use mobirnn::json::{ToValue, Value};
use mobirnn::server::{frame, protocol, Client, EventServer, Request, Response, Server};
use mobirnn::simulator::Target;
use mobirnn::util::Stats;

struct ScenarioResult {
    name: &'static str,
    requests: usize,
    wall: Duration,
    wall_ms: Stats,
    shed: usize,
    expired: usize,
    mean_batch: f64,
}

impl ScenarioResult {
    fn rps(&self) -> f64 {
        self.requests as f64 / self.wall.as_secs_f64()
    }
}

/// Window fixture: deterministic pseudo-data, one flat window per index.
fn window(shape: ModelShape, i: usize) -> Vec<f32> {
    let n = shape.seq_len * shape.input_dim;
    (0..n).map(|j| ((i * 31 + j * 7) % 97) as f32 / 97.0 - 0.5).collect()
}

/// Drive `total` classify calls from `n_clients` concurrent TCP
/// clients. `targets` rotates per request; empty means "let the policy
/// decide".
fn run_scenario(
    name: &'static str,
    addr: std::net::SocketAddr,
    shape: ModelShape,
    n_clients: usize,
    total: usize,
    targets: &[Target],
    binary: bool,
) -> ScenarioResult {
    let next = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|_| {
            let next = Arc::clone(&next);
            let targets = targets.to_vec();
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).expect("connect");
                if binary {
                    client.negotiate_binary().expect("hello proto 3");
                }
                let mut served = 0usize;
                let mut shed = 0usize;
                let mut walls = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= total {
                        break;
                    }
                    let req = Request::Classify {
                        id: Some(i as u64),
                        window: window(shape, i),
                        target: targets.get(i % targets.len().max(1)).copied(),
                        precision: None,
                        deadline_ms: None,
                        allow_degraded: false,
                    };
                    let c0 = Instant::now();
                    match client.call(&req).expect("call") {
                        Response::Result { outcome, .. } => {
                            assert!(outcome.class < shape.num_classes, "bad class");
                            served += 1;
                            walls.push(c0.elapsed().as_secs_f64() * 1e3);
                        }
                        Response::Error { code, .. } => {
                            assert_eq!(code.as_str(), "overloaded", "unexpected error");
                            shed += 1;
                        }
                        other => panic!("unexpected response {other:?}"),
                    }
                }
                (served, shed, walls)
            })
        })
        .collect();
    let mut requests = 0;
    let mut shed = 0;
    let mut wall_ms = Stats::new();
    for h in handles {
        let (s, e, walls) = h.join().expect("client thread");
        requests += s;
        shed += e;
        for w in walls {
            wall_ms.push(w);
        }
    }
    let wall = t0.elapsed();

    // Server-side counters for the emitted record.
    let mut client = Client::connect(addr).expect("stats connect");
    let (_, _, metrics) = client.stats().expect("stats");
    let expired = metrics.get("expired").as_usize().unwrap_or(0);
    let mean_batch = metrics.get("mean_batch_size").as_f64().unwrap_or(0.0);
    ScenarioResult { name, requests, wall, wall_ms, shed, expired, mean_batch }
}

fn print_scenario(r: &ScenarioResult) {
    println!(
        "serving/{:<12} {:>7.0} req/s  p50 {:>6.2} ms  p99 {:>6.2} ms  \
         shed {}  expired {}  mean_batch {:.2}",
        r.name,
        r.rps(),
        r.wall_ms.percentile(50.0),
        r.wall_ms.percentile(99.0),
        r.shed,
        r.expired,
        r.mean_batch,
    );
}

fn scenario_json(r: &ScenarioResult) -> Value {
    let mut entry = BTreeMap::new();
    entry.insert("requests".to_string(), Value::Num(r.requests as f64));
    entry.insert("throughput_rps".to_string(), Value::Num(r.rps()));
    entry.insert("p50_wall_ms".to_string(), Value::Num(r.wall_ms.percentile(50.0)));
    entry.insert("p99_wall_ms".to_string(), Value::Num(r.wall_ms.percentile(99.0)));
    entry.insert("shed".to_string(), Value::Num(r.shed as f64));
    entry.insert("expired".to_string(), Value::Num(r.expired as f64));
    entry.insert("mean_batch_size".to_string(), Value::Num(r.mean_batch));
    Value::Obj(entry)
}

/// Per-step streaming: each of `n_sessions` clients opens its own
/// session, advances it one frame per `classify_stream` call, then
/// closes. `requests` counts steps; `wall_ms` is per-step latency.
fn run_streaming_scenario(
    name: &'static str,
    addr: std::net::SocketAddr,
    shape: ModelShape,
    n_sessions: usize,
    steps_per_session: usize,
) -> ScenarioResult {
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_sessions)
        .map(|s| {
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).expect("connect");
                let session = client.open_session(None).expect("open_session");
                let mut walls = Vec::new();
                for t in 0..steps_per_session {
                    let frame: Vec<f32> = (0..shape.input_dim)
                        .map(|j| ((s * 131 + t * 31 + j * 7) % 97) as f32 / 97.0 - 0.5)
                        .collect();
                    let c0 = Instant::now();
                    let (classes, logits) =
                        client.classify_stream(session, &frame, t as u64).expect("stream");
                    assert_eq!(classes.len(), 1, "one step in, one class out");
                    assert!(classes[0] < shape.num_classes, "bad class");
                    assert_eq!(logits.len(), shape.num_classes);
                    walls.push(c0.elapsed().as_secs_f64() * 1e3);
                }
                let steps = client.close_session(session).expect("close");
                assert_eq!(steps as usize, steps_per_session);
                walls
            })
        })
        .collect();
    let mut requests = 0;
    let mut wall_ms = Stats::new();
    for h in handles {
        for w in h.join().expect("session thread") {
            requests += 1;
            wall_ms.push(w);
        }
    }
    let wall = t0.elapsed();

    let mut client = Client::connect(addr).expect("stats connect");
    let (_, _, metrics) = client.stats().expect("stats");
    let expired = metrics.get("sessions_expired").as_usize().unwrap_or(0);
    // Streams never batch (batch size is 1 by construction).
    ScenarioResult { name, requests, wall, wall_ms, shed: 0, expired, mean_batch: 1.0 }
}

/// One server over the three native CPU engines — single-thread,
/// multi-thread, and int8 quantized pools — sharing the random-weight
/// model (the quant engine packs it once at registration).
fn start_server(shape: ModelShape) -> Server {
    let model = Arc::new(random_model(shape, 42));
    let router = Router::builder()
        .shape(shape)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(2))
        .engine(Box::new(CpuMultiEngine::new(Arc::clone(&model), 4)))
        .engine(Box::new(CpuQuantEngine::from_f32(&model)))
        .engine(Box::new(CpuSingleEngine::new(model)))
        .build()
        .expect("router");
    Server::bind("127.0.0.1:0", router).expect("bind")
}

/// The same engine set behind the event-driven front-end (DESIGN.md
/// §12): a fixed pair of I/O threads multiplexing every connection.
fn start_event_server(shape: ModelShape, max_connections: usize) -> EventServer {
    let model = Arc::new(random_model(shape, 42));
    let router = Router::builder()
        .shape(shape)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(2))
        .engine(Box::new(CpuMultiEngine::new(Arc::clone(&model), 4)))
        .engine(Box::new(CpuQuantEngine::from_f32(&model)))
        .engine(Box::new(CpuSingleEngine::new(model)))
        .build()
        .expect("router");
    EventServer::builder()
        .io_threads(2)
        .max_connections(max_connections)
        .bind("127.0.0.1:0", router)
        .expect("bind event")
}

/// The fault-injected stack (DESIGN.md §15): primary pool fails 20% of
/// calls and spikes 5 ms latency on half of them; the multi-thread pool
/// is clean failover capacity. Breaker and watchdog knobs are tight so
/// a smoke run still exercises open/half-open transitions.
fn start_chaos_server(shape: ModelShape) -> Server {
    let model = Arc::new(random_model(shape, 42));
    let router = Router::builder()
        .shape(shape)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(2))
        .breaker(3, Duration::from_millis(100))
        .watchdog(Duration::from_millis(500))
        .fault_plan(
            FaultPlan::parse("cpu:fail_rate=0.2,latency_ms=5@p50,seed=17").expect("fault plan"),
        )
        .engine(Box::new(CpuSingleEngine::new(Arc::clone(&model))))
        .engine(Box::new(CpuMultiEngine::new(model, 4)))
        .build()
        .expect("router");
    Server::bind("127.0.0.1:0", router).expect("bind")
}

/// Drive deadline-budgeted classifies into the fault-injected server.
/// Unlike [`run_scenario`], typed failures are part of the contract
/// being measured: every request must RESOLVE — success, `overloaded`,
/// `retries_exhausted`, `deadline`, or `engine` — and nothing may hang.
/// Returns the scenario stats plus (typed_errors, watchdog_fired,
/// inflight_leaked, server_retries).
fn run_chaos_scenario(
    addr: std::net::SocketAddr,
    shape: ModelShape,
    n_clients: usize,
    total: usize,
    deadline: Duration,
) -> (ScenarioResult, usize, usize, usize, usize) {
    let next = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|_| {
            let next = Arc::clone(&next);
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).expect("connect");
                let mut served = 0usize;
                let mut typed = 0usize;
                let mut walls = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= total {
                        break;
                    }
                    let req = Request::Classify {
                        id: Some(i as u64),
                        window: window(shape, i),
                        target: None,
                        precision: None,
                        deadline_ms: Some(deadline.as_millis() as u64),
                        allow_degraded: false,
                    };
                    let c0 = Instant::now();
                    match client.call(&req).expect("call") {
                        Response::Result { outcome, .. } => {
                            assert!(outcome.class < shape.num_classes, "bad class");
                            served += 1;
                            walls.push(c0.elapsed().as_secs_f64() * 1e3);
                        }
                        Response::Error { code, .. } => {
                            assert!(
                                matches!(
                                    code.as_str(),
                                    "overloaded" | "retries_exhausted" | "deadline" | "engine"
                                ),
                                "untyped failure under chaos: {}",
                                code.as_str()
                            );
                            typed += 1;
                        }
                        other => panic!("unexpected response {other:?}"),
                    }
                }
                (served, typed, walls)
            })
        })
        .collect();
    let mut requests = 0;
    let mut typed = 0;
    let mut wall_ms = Stats::new();
    for h in handles {
        let (s, t, walls) = h.join().expect("chaos client thread");
        requests += s;
        typed += t;
        for w in walls {
            wall_ms.push(w);
        }
    }
    let wall = t0.elapsed();

    let mut client = Client::connect(addr).expect("stats connect");
    let (_, _, metrics) = client.stats().expect("stats");
    let expired = metrics.get("expired").as_usize().unwrap_or(0);
    let mean_batch = metrics.get("mean_batch_size").as_f64().unwrap_or(0.0);
    let shed = metrics.get("shed").as_usize().unwrap_or(0);
    let watchdog_fired = metrics.get("watchdog_fired").as_usize().unwrap_or(0);
    let retries = metrics.get("retries").as_usize().unwrap_or(0);
    let inflight = metrics.get("inflight");
    let leaked = ["gpu", "cpu", "cpu_multi", "cpu_quant"]
        .iter()
        .map(|k| inflight.get(k).as_usize().unwrap_or(0))
        .sum::<usize>();
    let result = ScenarioResult {
        name: "chaos",
        requests,
        wall,
        wall_ms,
        shed,
        expired,
        mean_batch,
    };
    (result, typed, watchdog_fired, leaked, retries)
}

/// Decode cost of ONE classify request, JSON line vs binary frame —
/// the per-request serialization tax the wire subsystem exists to cut.
/// Returns (json_ns_per_op, binary_ns_per_op).
fn decode_costs(shape: ModelShape, iters: usize) -> (f64, f64) {
    let req = Request::Classify {
        id: Some(7),
        window: window(shape, 3),
        target: None,
        precision: None,
        deadline_ms: None,
        allow_degraded: false,
    };
    let line = req.to_value().to_json();
    let encoded = frame::encode_request(&req);
    let t0 = Instant::now();
    for _ in 0..iters {
        let decoded = protocol::decode_line(std::hint::black_box(line.as_str()));
        std::hint::black_box(decoded.expect("json decode"));
    }
    let json_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    let t0 = Instant::now();
    for _ in 0..iters {
        let decoded = frame::decode_request(std::hint::black_box(encoded.as_slice()));
        std::hint::black_box(decoded.expect("frame decode"));
    }
    let binary_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    (json_ns, binary_ns)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var_os("MOBIRNN_BENCH_SMOKE").is_some();
    let shape = ModelShape::default();
    let (n_clients, total) = if smoke { (2, 8) } else { (8, 400) };

    // Scenario 1: everything through ONE pool — the serialized baseline.
    let single_srv = start_server(shape);
    let single = run_scenario(
        "single_pool",
        single_srv.addr(),
        shape,
        n_clients,
        total,
        &[Target::CpuSingle],
        false,
    );
    print_scenario(&single);
    drop(single_srv);

    // Scenario 2: alternate pools — batches overlap across workers.
    let dual_srv = start_server(shape);
    let dual = run_scenario(
        "dual_pool",
        dual_srv.addr(),
        shape,
        n_clients,
        total,
        &[Target::CpuSingle, Target::CpuMulti(4)],
        false,
    );
    print_scenario(&dual);
    drop(dual_srv);

    // Scenario 3: every request pinned to the int8 quantized pool
    // (DESIGN.md §10) — the full TCP → scheduler → quant-engine →
    // requantized-reply path, exercised end to end. In --smoke this is
    // the CI gate that keeps the quant engine wired into serving.
    let quant_srv = start_server(shape);
    let quant = run_scenario(
        "quant_pool",
        quant_srv.addr(),
        shape,
        n_clients,
        total,
        &[Target::CpuQuant],
        false,
    );
    print_scenario(&quant);
    drop(quant_srv);

    // Scenario 4: stateful streaming (DESIGN.md §11) — per-step
    // classify_stream against persistent sessions; p50/p99 here are
    // per-STEP, the latency a live client sees per frame.
    let (n_sessions, steps_each) = if smoke { (2, 8) } else { (8, 100) };
    let stream_srv = start_server(shape);
    let streaming =
        run_streaming_scenario("streaming", stream_srv.addr(), shape, n_sessions, steps_each);
    print_scenario(&streaming);
    drop(stream_srv);

    // Chaos scenario (DESIGN.md §15): seeded failure storm under
    // per-request deadlines — resolution, bounded latency, no leaks.
    let chaos_deadline = Duration::from_millis(1000);
    let chaos_srv = start_chaos_server(shape);
    let (chaos, chaos_typed, chaos_watchdog, chaos_leaked, chaos_retries) =
        run_chaos_scenario(chaos_srv.addr(), shape, n_clients, total, chaos_deadline);
    print_scenario(&chaos);
    println!(
        "serving/chaos: typed_errors {chaos_typed}  retries {chaos_retries}  \
         watchdog_fired {chaos_watchdog}  inflight_leaked {chaos_leaked}"
    );
    drop(chaos_srv);

    // Scenario 5 (DESIGN.md §12): binary_vs_json — the event-driven
    // server first driven over JSON lines, then over binary frames,
    // while ~1k idle connections stay open on the same two I/O threads.
    let idle_conns = 1024usize;
    let event_srv = start_event_server(shape, idle_conns + n_clients + 8);
    let mut idle: Vec<Client> = (0..idle_conns)
        .map(|_| Client::connect(event_srv.addr()).expect("idle connect"))
        .collect();
    // Every idle connection answers a ping: accepted, multiplexed, live.
    for c in idle.iter_mut() {
        c.ping().expect("idle ping");
    }
    let json_over = run_scenario(
        "json_event",
        event_srv.addr(),
        shape,
        n_clients,
        total,
        &[Target::CpuSingle],
        false,
    );
    print_scenario(&json_over);
    let binary_over = run_scenario(
        "binary_event",
        event_srv.addr(),
        shape,
        n_clients,
        total,
        &[Target::CpuSingle],
        true,
    );
    print_scenario(&binary_over);
    let accepted = event_srv.connections_accepted();
    drop(idle);
    drop(event_srv);

    let decode_iters = if smoke { 400 } else { 4000 };
    let (json_ns, binary_ns) = decode_costs(shape, decode_iters);
    let decode_ratio = json_ns / binary_ns.max(1e-9);
    println!(
        "wire/decode_classify: json {json_ns:.0} ns/op, binary {binary_ns:.0} ns/op \
         ({decode_ratio:.1}x cheaper)"
    );

    println!(
        "serving/dual_pool_speedup: {:.2}x (pipelined vs serialized dispatch)",
        dual.rps() / single.rps().max(1e-9)
    );
    println!(
        "serving/quant_pool_speedup: {:.2}x (int8 pool vs f32 single pool)",
        quant.rps() / single.rps().max(1e-9)
    );

    if smoke {
        // Functional gate for CI: every request completed (no deadlock,
        // no shed at tiny N) and every pool actually served traffic —
        // including the quantized one.
        assert_eq!(single.requests, total, "smoke: all single-pool requests served");
        assert_eq!(dual.requests, total, "smoke: all dual-pool requests served");
        assert_eq!(quant.requests, total, "smoke: all quant-pool requests served");
        assert_eq!(single.shed + dual.shed + quant.shed, 0, "smoke: no shed at tiny N");
        assert_eq!(
            streaming.requests,
            n_sessions * steps_each,
            "smoke: every streamed step served"
        );
        assert_eq!(streaming.expired, 0, "smoke: no session expired mid-stream");
        assert_eq!(json_over.requests, total, "smoke: all json-over-event requests served");
        assert_eq!(binary_over.requests, total, "smoke: all binary-over-event requests served");
        assert!(
            accepted >= idle_conns as u64,
            "smoke: event server must sustain >=1k concurrent connections (accepted {accepted})"
        );
        // Chaos gate: nothing hangs, nothing leaks, successes stay
        // inside deadline + watchdog grace.
        assert_eq!(
            chaos.requests + chaos_typed,
            total,
            "chaos: every request must resolve (success or typed error)"
        );
        assert!(chaos.requests > 0, "chaos: some requests must survive a 20% storm");
        if chaos.requests > 0 {
            let p99 = chaos.wall_ms.percentile(99.0);
            let bound = (chaos_deadline + Duration::from_millis(500)).as_secs_f64() * 1e3;
            assert!(
                p99 <= bound,
                "chaos: successful p99 {p99:.1} ms exceeds deadline + watchdog grace {bound:.0} ms"
            );
        }
        assert_eq!(chaos_leaked, 0, "chaos: in-flight gauges must drain to zero");
        assert!(
            decode_ratio >= 5.0,
            "smoke: binary classify decode must be >=5x cheaper than JSON \
             (json {json_ns:.0} ns, binary {binary_ns:.0} ns, {decode_ratio:.1}x)"
        );
        println!("serving/smoke: OK ({total} requests per scenario, timings ignored)");
        return;
    }

    let mut cases = BTreeMap::new();
    cases.insert("serving/single_pool".to_string(), scenario_json(&single));
    cases.insert("serving/dual_pool".to_string(), scenario_json(&dual));
    cases.insert("serving/quant_pool".to_string(), scenario_json(&quant));
    cases.insert("serving/streaming".to_string(), scenario_json(&streaming));
    let mut chaos_entry = match scenario_json(&chaos) {
        Value::Obj(map) => map,
        _ => unreachable!("scenario_json returns an object"),
    };
    chaos_entry.insert("typed_errors".to_string(), Value::Num(chaos_typed as f64));
    chaos_entry.insert("retries".to_string(), Value::Num(chaos_retries as f64));
    chaos_entry.insert("watchdog_fired".to_string(), Value::Num(chaos_watchdog as f64));
    chaos_entry.insert("inflight_leaked".to_string(), Value::Num(chaos_leaked as f64));
    chaos_entry
        .insert("deadline_ms".to_string(), Value::Num(chaos_deadline.as_millis() as f64));
    cases.insert("serving/chaos".to_string(), Value::Obj(chaos_entry));
    cases.insert("serving/json_over_event".to_string(), scenario_json(&json_over));
    cases.insert("serving/binary_over_event".to_string(), scenario_json(&binary_over));
    let mut wire = BTreeMap::new();
    wire.insert("json_decode_ns".to_string(), Value::Num(json_ns));
    wire.insert("binary_decode_ns".to_string(), Value::Num(binary_ns));
    wire.insert("decode_speedup".to_string(), Value::Num(decode_ratio));
    wire.insert("idle_connections".to_string(), Value::Num(idle_conns as f64));
    cases.insert("wire/binary_vs_json".to_string(), Value::Obj(wire));
    let mut root = BTreeMap::new();
    root.insert("format".to_string(), Value::from("mobirnn-bench"));
    root.insert("version".to_string(), Value::from(1usize));
    root.insert("bench".to_string(), Value::from("serving"));
    root.insert("n_clients".to_string(), Value::Num(n_clients as f64));
    root.insert("cases".to_string(), Value::Obj(cases));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_serving.json");
    std::fs::write(&path, Value::Obj(root).to_json()).expect("write BENCH_serving.json");
    println!("wrote {}", path.display());
}
