//! Bench E6 (paper Fig 6): multithreaded CPU vs GPU. Prints the figure;
//! also times the REAL native engine single- vs pooled-threads on this
//! host (batch of 8 windows) — the actual CPU serving path.

use std::sync::Arc;

use mobirnn::bench::bench_auto;
use mobirnn::config::{Manifest, ModelShape};
use mobirnn::figures;
use mobirnn::lstm::{BatchArena, LstmModel, ThreadedLstm, WeightFile};
use mobirnn::simulator::DeviceProfile;
use mobirnn::tensor::Tensor;

fn main() {
    let n5 = DeviceProfile::nexus5();
    figures::print_fig6(&figures::fig6(&n5));
    println!();
    bench_auto("fig6/regenerate", 50.0, || {
        std::hint::black_box(figures::fig6(&n5));
    });

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("(artifacts not built; skipping native-engine benches)");
        return;
    }
    let man = Manifest::load(dir).unwrap();
    let shape = ModelShape::default();
    let wf = WeightFile::load(man.path("weights_L2_H32.mrnw")).unwrap();
    let model = Arc::new(LstmModel::from_weight_file(shape, &wf).unwrap());
    let ds = mobirnn::har::generate(8, 3);
    let x = Tensor::new(
        vec![8, shape.seq_len, shape.input_dim],
        (0..8).flat_map(|i| ds.window(i).to_vec()).collect(),
    );

    let mut arena = BatchArena::with_capacity(shape, 8);
    bench_auto("fig6/native_single_b8", 100.0, || {
        std::hint::black_box(model.forward_batch(&x, &mut arena));
    });
    for threads in [2usize, 4] {
        let pool = ThreadedLstm::new(Arc::clone(&model), threads);
        bench_auto(&format!("fig6/native_pool{threads}_b8"), 100.0, || {
            std::hint::black_box(pool.forward_batch(&x));
        });
    }
}
