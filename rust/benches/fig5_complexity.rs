//! Bench E5 (paper Fig 5): speedup vs model complexity. Prints the
//! figure; times the sweep and the largest simulated configuration
//! (H=256 is the most DES work: 512 launches with memory-roofline math).

use mobirnn::bench::bench_auto;
use mobirnn::config::ModelShape;
use mobirnn::figures;
use mobirnn::simulator::{simulate_inference, DeviceProfile, Factorization, Target};

fn main() {
    let n5 = DeviceProfile::nexus5();
    figures::print_fig5(&figures::fig5(&n5));
    println!();
    bench_auto("fig5/regenerate_full_sweep", 50.0, || {
        std::hint::black_box(figures::fig5(&n5));
    });
    bench_auto("fig5/sim_gpu_2l256h", 20.0, || {
        std::hint::black_box(simulate_inference(
            &n5,
            ModelShape::new(2, 256),
            1,
            Target::Gpu(Factorization::Coarse),
            0.0,
        ));
    });
}
