//! Bench E2 (paper Fig 2): fine vs coarse factorization of one gate GEMM.
//! Prints the figure rows, then times the simulated execution of each
//! strategy (the simulator itself is part of the measured hot path for
//! the cost-model policy, so its speed matters).

use mobirnn::bench::bench_auto;
use mobirnn::config::ModelShape;
use mobirnn::figures;
use mobirnn::simulator::{build_trace_with_slots, gpu_run, DeviceProfile, Factorization, TraceOpts};

fn main() {
    let profile = DeviceProfile::nexus5();
    figures::print_fig2(&figures::fig2(&profile));
    println!();

    let shape = ModelShape { num_layers: 1, hidden: 30, input_dim: 2, seq_len: 1, num_classes: 6 };
    for (name, fact) in [("fine", Factorization::Fine), ("coarse", Factorization::Coarse)] {
        let trace = build_trace_with_slots(shape, 1, fact, &TraceOpts::mobirnn(), profile.gpu_slots);
        bench_auto(&format!("fig2/sim_gemm_{name}"), 20.0, || {
            std::hint::black_box(gpu_run(&profile, &trace, 0.0, 0));
        });
        bench_auto(&format!("fig2/build_trace_{name}"), 20.0, || {
            std::hint::black_box(build_trace_with_slots(
                shape, 1, fact, &TraceOpts::mobirnn(), profile.gpu_slots,
            ));
        });
    }
}
