//! Bench E7 (paper Fig 7): latency under background GPU load, plus the
//! policy-decision hot path (the router consults the cost model per
//! batch, so `decide` must stay cheap).

use mobirnn::bench::bench_auto;
use mobirnn::config::ModelShape;
use mobirnn::coordinator::policy::{LoadSnapshot, OffloadPolicy};
use mobirnn::figures;
use mobirnn::simulator::DeviceProfile;

fn main() {
    let n6p = DeviceProfile::nexus6p();
    figures::print_fig7(&figures::fig7(&n6p, 30, 42));
    println!();
    bench_auto("fig7/regenerate_30_samples", 50.0, || {
        std::hint::black_box(figures::fig7(&n6p, 30, 42));
    });

    let shape = ModelShape::default();
    for (name, load) in [
        ("idle", LoadSnapshot::default()),
        ("high", LoadSnapshot { gpu_util: 0.85, cpu_util: 0.85, ..Default::default() }),
    ] {
        bench_auto(&format!("fig7/cost_model_decide_{name}"), 20.0, || {
            std::hint::black_box(OffloadPolicy::CostModel.decide(&n6p, shape, 1, load));
        });
    }
}
