//! §Perf hot-path microbenches — the real serving-path components on
//! this host. These are the numbers EXPERIMENTS.md §Perf tracks
//! before/after optimization:
//!
//!   - native LSTM cell + full-window forward (CPU serving target)
//!   - per-row GEMV path vs the batched time-major plan at B ∈ {1,2,4,8}
//!     (artifact-free: random weights, so it runs on every host)
//!   - `gemm_microbench/*`: the inner GEMM kernels in isolation at the
//!     HAR shape, dispatched-SIMD vs forced-scalar, reported as GFLOP/s
//!     (DESIGN.md §13)
//!   - `tail_microbench/*`: the fused LSTM gate tail in isolation,
//!     dispatched vs libm-scalar vs Padé-scalar, reported as elem/s
//!     (DESIGN.md §14); `--smoke` gates the b8 batched time and the
//!     tail speedup on SIMD hosts
//!   - PJRT execute (GPU serving target) at batch 1 and 8
//!   - batch planning, policy decision, JSON wire codec, histogram record
//!
//! Every case also lands in `BENCH_hotpath.json` next to Cargo.toml —
//! the machine-readable seed of the perf trajectory (mean/stddev ns per
//! case, plus which kernel path timed it; a `machine` block pins the
//! detected ISA and core count so trajectories are comparable across
//! hosts; schema documented in EXPERIMENTS.md §Perf).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use mobirnn::bench::{bench, bench_auto, bench_per_row_vs_batched, bench_quant_vs_f32, BenchResult};
use mobirnn::config::{Manifest, ModelShape};
use mobirnn::coordinator::metrics::Histogram;
use mobirnn::coordinator::plan_batch;
use mobirnn::coordinator::policy::{LoadSnapshot, OffloadPolicy};
use mobirnn::har;
use mobirnn::json::Value;
use mobirnn::lstm::cell::{lstm_cell, CellScratch};
use mobirnn::lstm::model::InferenceState;
use mobirnn::lstm::{LstmModel, WeightFile};
use mobirnn::runtime::Runtime;
use mobirnn::simulator::DeviceProfile;
use mobirnn::tensor::Tensor;

/// Serialize every case to `<manifest dir>/BENCH_hotpath.json`.
/// `artifacts_present` marks partial runs: without `rust/artifacts/`
/// the artifact-gated cases (native cell/forward_window, pjrt) are
/// absent, and the flag keeps that from reading as a dropped case.
fn write_bench_json(results: &[BenchResult], artifacts_present: bool) {
    let isa = mobirnn::kernel::active().as_str();
    let mut cases = BTreeMap::new();
    for r in results {
        let mut entry = BTreeMap::new();
        entry.insert("mean_ns".to_string(), Value::Num(r.mean_ns()));
        entry.insert("stddev_ns".to_string(), Value::Num(r.stats.stddev()));
        entry.insert("samples".to_string(), Value::Num(r.stats.len() as f64));
        entry.insert(
            "iters_per_sample".to_string(),
            Value::Num(r.iters_per_sample as f64),
        );
        // Which kernel path timed this case: the `*_scalar` micro cases
        // call the scalar oracles directly; everything else ran on the
        // dispatched ISA.
        let kernel = if r.name.ends_with("_scalar") { "scalar" } else { isa };
        entry.insert("kernel".to_string(), Value::from(kernel));
        cases.insert(r.name.clone(), Value::Obj(entry));
    }
    let mut machine = BTreeMap::new();
    machine.insert("kernel_isa".to_string(), Value::from(isa));
    machine.insert(
        "cores".to_string(),
        Value::from(std::thread::available_parallelism().map_or(1, |n| n.get())),
    );
    let mut root = BTreeMap::new();
    root.insert("format".to_string(), Value::from("mobirnn-bench"));
    root.insert("version".to_string(), Value::from(2usize));
    root.insert("bench".to_string(), Value::from("hotpath"));
    root.insert("artifacts_present".to_string(), Value::from(artifacts_present));
    root.insert("machine".to_string(), Value::Obj(machine));
    root.insert("cases".to_string(), Value::Obj(cases));
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_hotpath.json");
    std::fs::write(&path, Value::Obj(root).to_json()).expect("write BENCH_hotpath.json");
    println!("wrote {}", path.display());
}

fn main() {
    let mut all: Vec<BenchResult> = Vec::new();
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let man = if dir.join("manifest.json").exists() {
        Some(Manifest::load(dir).unwrap())
    } else {
        eprintln!("(artifacts not built; native/PJRT benches use random weights only)");
        None
    };
    let shape = ModelShape::default();
    let ds = har::generate(8, 1);

    // --- native engine (trained weights, artifact-gated) ---
    if let Some(man) = &man {
        let wf = WeightFile::load(man.path("weights_L2_H32.mrnw")).unwrap();
        let model = Arc::new(LstmModel::from_weight_file(shape, &wf).unwrap());
        let mut st = InferenceState::new(shape);
        let window = ds.window(0).to_vec();

        // One cell step (the innermost kernel).
        let layer0 = wf.to_model_weights(shape).unwrap().0.remove(0);
        let mut h = vec![0.0f32; shape.hidden];
        let mut c = vec![0.0f32; shape.hidden];
        let mut scratch = CellScratch::new(shape.hidden);
        all.push(bench("hotpath/native_cell_step", 100, 20, 10_000, || {
            lstm_cell(&layer0, &window[..9], &mut h, &mut c, &mut scratch);
        }));

        all.push(bench_auto("hotpath/native_forward_window", 100.0, || {
            std::hint::black_box(model.forward_window(&window, &mut st));
        }));

        // Allocation discipline check: forward_window must not allocate
        // per call beyond the logits vec (ablation of §3.2 on CPU).
        let t0 = Instant::now();
        for _ in 0..1000 {
            std::hint::black_box(model.forward_window(&window, &mut st));
        }
        println!(
            "hotpath/native_throughput_1core: {:.0} windows/s",
            1000.0 / t0.elapsed().as_secs_f64()
        );
    }

    // --- per-row path vs batched time-major plan (artifact-free) ---
    // The tentpole ablation: the same math as B forward_window calls vs
    // one pass through the BatchArena plan (DESIGN.md §8). The batched
    // numbers must be no slower at B=1 and faster at B=8.
    let per_row_vs_batched = bench_per_row_vs_batched("hotpath", 80.0);

    // --- int8 quantized path vs the f32 batched plan (artifact-free) ---
    // DESIGN.md §10: pre-packed per-channel int8 weights, integer GEMMs,
    // fast rational tail; the speedup lines reuse the native_batched_b*
    // timings above. Acceptance gate tracked in EXPERIMENTS.md §Perf:
    // native_quant_b8 mean ≤ 0.6× native_batched_b8.
    all.extend(bench_quant_vs_f32("hotpath", 80.0, &per_row_vs_batched));
    all.extend(per_row_vs_batched);

    // --- inner GEMM kernels in isolation (DESIGN.md §13) ---
    // The HAR hot-path shape: B=8 rows through a layer's recurrent half
    // ([8, 64] × [64, 128], K = I+H at H=32, N = 4H). Dispatched kernels
    // vs the scalar oracles, reported as GFLOP/s (2·M·K·N per iter; the
    // int8 cases count the same "effective" flops so the ratio reads as
    // per-element speedup).
    {
        use mobirnn::lstm::quant::{
            quant_matmul_into, quant_matmul_into_scalar, PackedQuantMatrix,
        };
        use mobirnn::tensor::{matmul_into, matmul_into_scalar};
        use mobirnn::util::Rng;

        let (m, k, n) = (8usize, 64usize, 128usize);
        let flops = (2 * m * k * n) as f64;
        let mut rng = Rng::new(77);
        let a: Vec<f32> = (0..m * k).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut out = vec![0.0f32; m * n];
        all.push(bench_auto("gemm_microbench/gemm_f32", 60.0, || {
            out.fill(0.0);
            matmul_into(&mut out, &a, &w, m, k, n);
        }));
        all.push(bench_auto("gemm_microbench/gemm_f32_scalar", 60.0, || {
            out.fill(0.0);
            matmul_into_scalar(&mut out, &a, &w, m, k, n);
        }));
        let wq = PackedQuantMatrix::pack(&w, k, n);
        let qa: Vec<i8> = (0..m * k).map(|_| rng.uniform(-127.0, 127.0) as i8).collect();
        let mut qacc = vec![0i32; m * n];
        all.push(bench_auto("gemm_microbench/gemm_i8", 60.0, || {
            qacc.fill(0);
            quant_matmul_into(&mut qacc, &qa, &wq, m);
        }));
        all.push(bench_auto("gemm_microbench/gemm_i8_scalar", 60.0, || {
            qacc.fill(0);
            quant_matmul_into_scalar(&mut qacc, &qa, &wq, m);
        }));
        for r in all.iter().rev().take(4).rev() {
            println!("{}: {:.2} GFLOP/s", r.name, flops / r.mean_ns());
        }
    }

    // --- fused LSTM gate tail in isolation (DESIGN.md §14) ---
    // The B=8 HAR step tail: [8, 4H] gate pre-activations → h/c update.
    // Dispatched kernel vs the libm oracle vs the scalar Padé chain
    // (which the vector kernels are bit-identical to), reported as
    // elem/s of updated state.
    {
        use mobirnn::lstm::{lstm_tail, lstm_tail_pade_scalar, lstm_tail_scalar};
        use mobirnn::util::Rng;

        let (rows, hid) = (8usize, shape.hidden);
        let mut rng = Rng::new(78);
        let gates: Vec<f32> = (0..rows * 4 * hid).map(|_| rng.uniform(-4.0, 4.0)).collect();
        let mut h = vec![0.0f32; rows * hid];
        let mut c = vec![0.0f32; rows * hid];
        all.push(bench_auto("tail_microbench/tail_f32", 60.0, || {
            lstm_tail(&gates, &mut h, &mut c, rows, hid);
        }));
        all.push(bench_auto("tail_microbench/tail_f32_libm_scalar", 60.0, || {
            lstm_tail_scalar(&gates, &mut h, &mut c, rows, hid);
        }));
        all.push(bench_auto("tail_microbench/tail_f32_pade_scalar", 60.0, || {
            lstm_tail_pade_scalar(&gates, &mut h, &mut c, rows, hid);
        }));
        let elems = (rows * hid) as f64;
        for r in all.iter().rev().take(3).rev() {
            println!("{}: {:.0} Melem/s", r.name, elems * 1e3 / r.mean_ns());
        }
    }

    // --- PJRT path ---
    if let Some(man) = &man {
        let rt = Runtime::start(man).unwrap();
        for batch in [1usize, 8] {
            let name = shape.variant_name(batch);
            rt.preload(&name).unwrap();
            let mut data = Vec::new();
            for i in 0..batch {
                data.extend_from_slice(ds.window(i));
            }
            let x = Tensor::new(vec![batch, shape.seq_len, shape.input_dim], data);
            all.push(bench_auto(&format!("hotpath/pjrt_execute_b{batch}"), 150.0, || {
                std::hint::black_box(rt.execute(&name, x.clone()).unwrap());
            }));
        }
        println!(
            "hotpath/pjrt_mean_exec_reported: {:.1} µs",
            rt.mean_exec_ns() / 1e3
        );
    }

    // --- coordinator components ---
    all.push(bench("hotpath/plan_batch", 100, 20, 100_000, || {
        std::hint::black_box(plan_batch(5, &[1, 2, 4, 8]));
    }));
    let profile = DeviceProfile::nexus5();
    all.push(bench("hotpath/policy_threshold", 100, 20, 100_000, || {
        std::hint::black_box(
            OffloadPolicy::Threshold { gpu_threshold: 0.6 }.decide(
                &profile,
                shape,
                1,
                LoadSnapshot { gpu_util: 0.3, cpu_util: 0.1, ..Default::default() },
            ),
        );
    }));
    all.push(bench("hotpath/policy_cost_model", 10, 20, 100, || {
        std::hint::black_box(OffloadPolicy::CostModel.decide(
            &profile,
            shape,
            1,
            LoadSnapshot { gpu_util: 0.3, cpu_util: 0.1, ..Default::default() },
        ));
    }));
    let mut cache = mobirnn::coordinator::DecisionCache::new();
    all.push(bench("hotpath/policy_cost_model_cached", 100, 20, 100_000, || {
        std::hint::black_box(cache.decide(
            &OffloadPolicy::CostModel,
            &profile,
            shape,
            1,
            LoadSnapshot { gpu_util: 0.3, cpu_util: 0.1, ..Default::default() },
        ));
    }));
    let hist = Histogram::new();
    all.push(bench("hotpath/histogram_record", 100, 20, 100_000, || {
        hist.record(12_345);
    }));

    // --- wire codec (1152-float classify line, protocol v2) ---
    let window = ds.window(0);
    let line = {
        use mobirnn::json::ToValue;
        use mobirnn::server::Request;
        Request::Classify {
            id: Some(7),
            window: window.to_vec(),
            target: None,
            precision: None,
            deadline_ms: None,
            allow_degraded: false,
        }
        .to_value()
        .to_json()
    };
    println!("hotpath/wire_line_bytes: {}", line.len());
    all.push(bench_auto("hotpath/json_parse_classify", 50.0, || {
        std::hint::black_box(mobirnn::json::parse(&line).unwrap());
    }));
    let parsed = mobirnn::json::parse(&line).unwrap();
    all.push(bench_auto("hotpath/json_serialize_classify", 50.0, || {
        std::hint::black_box(parsed.to_json());
    }));

    write_bench_json(&all, man.is_some());

    // --- CI smoke gate (DESIGN.md §14 acceptance) ---
    // `--smoke` asserts the vectorized-tail win on SIMD hosts: the f32
    // batched b8 hot path must land at ≤ 0.75× of the PR 7 baseline
    // (2.31 ms, BENCH_hotpath.json history), and the dispatched tail
    // must beat the libm scalar tail by ≥ 2× in isolation. Skipped on
    // scalar-only hosts / under MOBIRNN_FORCE_SCALAR, where the tail IS
    // libm by contract.
    if std::env::args().any(|a| a == "--smoke") {
        const PR7_BASELINE_B8_MS: f64 = 2.31;
        if mobirnn::kernel::active() == mobirnn::kernel::KernelIsa::Scalar {
            println!("smoke: scalar kernels active, tail perf gate skipped");
        } else {
            let mean_ms = |name: &str| {
                all.iter()
                    .find(|r| r.name == name)
                    .unwrap_or_else(|| panic!("smoke: case {name} missing"))
                    .mean_ns()
                    / 1e6
            };
            let b8 = mean_ms("hotpath/native_batched_b8");
            let gate = 0.75 * PR7_BASELINE_B8_MS;
            assert!(
                b8 <= gate,
                "smoke: native_batched_b8 {b8:.3} ms > {gate:.3} ms (0.75× PR 7 baseline)"
            );
            let tail = mean_ms("tail_microbench/tail_f32");
            let libm = mean_ms("tail_microbench/tail_f32_libm_scalar");
            assert!(
                tail * 2.0 <= libm,
                "smoke: dispatched tail {tail:.4} ms not ≥2× faster than libm {libm:.4} ms"
            );
            println!(
                "smoke: b8 {b8:.3} ms ≤ {gate:.3} ms, tail {:.1}× over libm — PASS",
                libm / tail
            );
        }
    }
}
