//! Ablation benches (DESIGN.md A1–A3): each §3.2/§3.3 optimization
//! toggled off, on the simulated Nexus 5 — quantifying what each buys.
//! Plus A4, measured for REAL on this host: the per-row GEMV path vs the
//! batched time-major plan (DESIGN.md §8) at B ∈ {1, 2, 4, 8} — the
//! work-unit coarsening applied to the batch dimension. Results land in
//! EXPERIMENTS.md §Perf.

use mobirnn::bench::{bench_auto, bench_per_row_vs_batched, bench_quant_vs_f32};
use mobirnn::config::ModelShape;
use mobirnn::simulator::{simulate_gpu_with_opts, DeviceProfile, Factorization, TraceOpts};

fn main() {
    let p = DeviceProfile::nexus5();
    let shape = ModelShape::default();
    let base = TraceOpts::mobirnn();
    let cases: Vec<(&str, TraceOpts)> = vec![
        ("mobirnn_all_opts", base),
        ("a2_split_gemm", TraceOpts { combined_gemm: false, ..base }),
        ("a2_unfused_pointwise", TraceOpts { fused_pointwise: false, ..base }),
        ("a1_no_memory_pool", TraceOpts { mem_pool: false, ..base }),
        ("a3_divergent_kernels", TraceOpts { divergence_free: false, ..base }),
        ("naive_port", TraceOpts::naive()),
    ];

    println!("== Ablations: simulated ms/inference (2l/32h, Nexus 5) ==");
    let baseline = simulate_gpu_with_opts(&p, shape, 1, Factorization::Coarse, &base, 0.0);
    for (name, opts) in &cases {
        let ns = simulate_gpu_with_opts(&p, shape, 1, Factorization::Coarse, opts, 0.0);
        println!(
            "{name:<24} {:>8.1} ms   {:>5.2}x",
            ns as f64 / 1e6,
            ns as f64 / baseline as f64
        );
    }
    println!("\n(simulator cost of each ablated configuration)");
    for (name, opts) in &cases {
        bench_auto(&format!("ablation/{name}"), 20.0, || {
            std::hint::black_box(simulate_gpu_with_opts(
                &p, shape, 1, Factorization::Coarse, opts, 0.0,
            ));
        });
    }

    // A4: per-row GEMV path vs the batched time-major plan, measured for
    // real on this host (2l/32h, 128x9 windows, random weights) — the
    // same fixture the hotpath bench records into BENCH_hotpath.json.
    println!("\n== A4: per-row vs batched native plan (real host timing) ==");
    let a4 = bench_per_row_vs_batched("ablation", 60.0);

    // A5: the f32 batched plan vs the int8 quantized plan (DESIGN.md
    // §10), same fixture — the quantization ablation EXPERIMENTS.md
    // §Ablations tracks (precision tier as an optimization knob); the
    // speedup lines reuse A4's native_batched_b* timings.
    println!("\n== A5: f32 batched vs int8 quantized plan (real host timing) ==");
    let _ = bench_quant_vs_f32("ablation", 60.0, &a4);
}
