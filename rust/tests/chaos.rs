//! Chaos integration (DESIGN.md §15): live routers and servers under
//! seeded fault storms. The contract being defended: every request
//! resolves — success, typed shed, typed `retries_exhausted`, or a
//! `degraded:"int8"` brownout answer — within its deadline plus the
//! watchdog grace. Zero hangs, zero silent drops, and breaker
//! transition arithmetic that matches the fault plan exactly.

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

use mobirnn::bench::random_model;
use mobirnn::config::ModelShape;
use mobirnn::coordinator::{
    CpuMultiEngine, CpuSingleEngine, OffloadPolicy, Precision, Router, ServeError,
};
use mobirnn::faults::{FaultPlan, StubEngine};
use mobirnn::lstm::StreamState;
use mobirnn::server::{Client, EventServer, Request, Response, Server};
use mobirnn::simulator::{Factorization, Target};

fn shape() -> ModelShape {
    ModelShape { num_layers: 1, hidden: 4, input_dim: 3, seq_len: 10, num_classes: 6 }
}

fn window(shape: ModelShape, seed: usize) -> Vec<f32> {
    let n = shape.seq_len * shape.input_dim;
    (0..n).map(|j| ((seed * 131 + j * 17) % 101) as f32 / 101.0 - 0.5).collect()
}

/// Poll until every in-flight gauge reads zero — a watchdog or failover
/// that leaks a gauge would park this forever, so bound it and fail.
fn assert_inflight_drains(router: &Router) {
    let metrics = &router.metrics;
    let deadline = Instant::now() + Duration::from_secs(2);
    loop {
        let total = metrics.inflight.gpu.load(Ordering::Relaxed)
            + metrics.inflight.cpu.load(Ordering::Relaxed)
            + metrics.inflight.cpu_multi.load(Ordering::Relaxed)
            + metrics.inflight.cpu_quant.load(Ordering::Relaxed);
        if total == 0 {
            return;
        }
        assert!(Instant::now() < deadline, "inflight gauges leaked: {total} still up");
        std::thread::sleep(Duration::from_millis(10));
    }
}

// ---- seeded failure storm (the acceptance scenario) ------------------

/// ≥20% injected failure on both failover pools, latency spikes, and a
/// permanent primary-pool death, under a 2 s deadline budget: every one
/// of 80 requests resolves typed within deadline + watchdog grace.
#[test]
fn seeded_storm_every_request_resolves_typed_within_deadline() {
    let s = shape();
    let plan = FaultPlan::parse(
        "cpu:fail_after=10;\
         cpu-multi:fail_rate=0.25,latency_ms=5@p50,seed=11;\
         pjrt:fail_rate=0.25,latency_ms=5@p50,seed=13",
    )
    .unwrap();
    let router = Router::builder()
        .shape(s)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(1))
        .breaker(3, Duration::from_millis(200))
        .watchdog(Duration::from_millis(500))
        .fault_plan(plan)
        .engine(Box::new(StubEngine::new(Target::CpuSingle, s.num_classes)))
        .engine(Box::new(StubEngine::new(Target::CpuMulti(2), s.num_classes)))
        .engine(Box::new(StubEngine::new(Target::Gpu(Factorization::Coarse), s.num_classes)))
        .build()
        .unwrap();

    let n = 80;
    let deadline = Duration::from_secs(2);
    let t0 = Instant::now();
    let receivers: Vec<_> = (0..n)
        .map(|i| {
            let opts = mobirnn::coordinator::ClassifyOptions {
                deadline: Some(deadline),
                ..Default::default()
            };
            router.submit_with(window(s, i), opts).unwrap()
        })
        .collect();

    // Deadline (2 s) + watchdog grace (500 ms) + scheduling slack.
    let bound = deadline + Duration::from_millis(500) + Duration::from_secs(1);
    let (mut ok, mut typed) = (0u32, 0u32);
    for rx in receivers {
        let wait = bound.saturating_sub(t0.elapsed()).max(Duration::from_millis(50));
        match rx.recv_timeout(wait) {
            Ok(Ok(reply)) => {
                assert_eq!(reply.class, 1, "StubEngine always scores class 1");
                ok += 1;
            }
            Ok(Err(
                ServeError::RetriesExhausted
                | ServeError::DeadlineExceeded
                | ServeError::Overloaded
                | ServeError::EngineFailure(_),
            )) => typed += 1,
            Ok(Err(other)) => panic!("unexpected error kind in storm: {other}"),
            Err(_) => panic!("request outlived deadline + watchdog grace: silent drop"),
        }
    }
    assert_eq!(ok + typed, n as u32);
    assert!(ok > 0, "some requests must survive the storm");

    let m = &router.metrics;
    assert!(m.retries.load(Ordering::Relaxed) > 0, "primary death must force failover");
    assert!(
        m.breaker_open.load(Ordering::Relaxed) >= 1,
        "a permanently dead pool must trip its breaker"
    );
    assert_inflight_drains(&router);
}

// ---- breaker state machine, deterministically ------------------------

/// `fail_first=3` against threshold 3: exactly one open, one half-open
/// probe, one close — and the open window sheds typed, not queued.
#[test]
fn breaker_opens_sheds_probes_and_recovers() {
    let s = shape();
    let router = Router::builder()
        .shape(s)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(1))
        .breaker(3, Duration::from_millis(250))
        .fault_plan(FaultPlan::parse("cpu:fail_first=3").unwrap())
        .engine(Box::new(StubEngine::new(Target::CpuSingle, s.num_classes)))
        .build()
        .unwrap();
    let m = Arc::clone(&router.metrics);

    // Three failures trip the breaker (single pool, no deadline: the
    // legacy typed EngineFailure terminal).
    for i in 0..3 {
        let err = router.classify(window(s, i)).unwrap_err();
        let serve = err.downcast_ref::<ServeError>().expect("typed serve error");
        assert!(matches!(serve, ServeError::EngineFailure(_)), "got {serve}");
    }
    assert_eq!(m.breaker_open.load(Ordering::Relaxed), 1);

    // Open + inside cooldown: the scheduler sheds instead of queueing
    // work against a pool known to be down.
    let err = router.classify(window(s, 3)).unwrap_err();
    assert!(
        matches!(err.downcast_ref::<ServeError>(), Some(ServeError::Overloaded)),
        "open breaker must shed typed, got {err:#}"
    );
    assert_eq!(m.shed.load(Ordering::Relaxed), 1);

    // Cooldown elapses: the next request is the half-open probe; it
    // succeeds (fail_first spent) and closes the breaker.
    std::thread::sleep(Duration::from_millis(400));
    let reply = router.classify(window(s, 4)).unwrap();
    assert_eq!(reply.class, 1);
    assert_eq!(m.breaker_half_open.load(Ordering::Relaxed), 1);
    assert_eq!(m.breaker_closed.load(Ordering::Relaxed), 1);
    assert_eq!(m.breaker_open.load(Ordering::Relaxed), 1, "no second trip");
}

// ---- all pools down: termination, typed, exactly once ----------------

/// With every pool failing and no deadline, each request terminates in
/// ONE typed EngineFailure — no hang, no duplicate reply (the seed bug:
/// a fully-tried batch could requeue onto the same dead pool forever).
#[test]
fn all_pools_down_terminates_typed_without_duplicates() {
    let s = shape();
    let router = Router::builder()
        .shape(s)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(1))
        .breaker(1000, Duration::from_secs(1))
        .fault_plan(FaultPlan::parse("*:fail_rate=1").unwrap())
        .engine(Box::new(StubEngine::new(Target::CpuSingle, s.num_classes)))
        .engine(Box::new(StubEngine::new(Target::CpuMulti(2), s.num_classes)))
        .build()
        .unwrap();

    for i in 0..4 {
        let rx = router.submit(window(s, i)).unwrap();
        match rx.recv_timeout(Duration::from_secs(5)) {
            Ok(Err(ServeError::EngineFailure(msg))) => {
                assert!(msg.contains("all engine pools"), "unexpected msg: {msg}")
            }
            other => panic!("expected one typed EngineFailure, got {other:?}"),
        }
        // Exactly one reply: the sink is spent, the channel closes.
        assert!(rx.recv_timeout(Duration::from_millis(100)).is_err(), "duplicate reply");
    }
    assert_inflight_drains(&router);
}

/// The same dead cluster under a deadline budget: capped exponential
/// backoff consumes the budget, then the typed `retries_exhausted`
/// terminal fires — before the caller's own deadline would.
#[test]
fn dead_cluster_with_deadline_returns_retries_exhausted() {
    let s = shape();
    let router = Router::builder()
        .shape(s)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(1))
        .breaker(1000, Duration::from_secs(1))
        .fault_plan(FaultPlan::parse("*:fail_rate=1").unwrap())
        .engine(Box::new(StubEngine::new(Target::CpuSingle, s.num_classes)))
        .engine(Box::new(StubEngine::new(Target::CpuMulti(2), s.num_classes)))
        .build()
        .unwrap();

    let n = 3;
    for i in 0..n {
        let opts = mobirnn::coordinator::ClassifyOptions {
            deadline: Some(Duration::from_millis(300)),
            ..Default::default()
        };
        let t0 = Instant::now();
        let rx = router.submit_with(window(s, i), opts).unwrap();
        match rx.recv_timeout(Duration::from_secs(2)) {
            Ok(Err(ServeError::RetriesExhausted)) => {}
            other => panic!("expected retries_exhausted, got {other:?}"),
        }
        assert!(
            t0.elapsed() < Duration::from_millis(900),
            "budget exhaustion must not overshoot the deadline"
        );
    }
    let m = &router.metrics;
    assert_eq!(m.retries_exhausted.load(Ordering::Relaxed), n as u64);
    assert!(m.retries.load(Ordering::Relaxed) > 0, "the budget must buy real retries");
    assert_inflight_drains(&router);
}

// ---- session failover under concurrent stream steps ------------------

/// Real weights on both pools; the pinned pool dies mid-stream while a
/// second thread keeps classifying. The session migrates exactly once
/// and every served chunk's logits stay bit-for-bit equal to a local
/// single-model oracle — the fault layer fails BEFORE touching state,
/// so a failed chunk never half-advances h/c.
#[test]
fn stream_migrates_once_with_bit_exact_logits_under_concurrent_load() {
    let s = ModelShape { num_layers: 2, hidden: 8, input_dim: 3, seq_len: 12, num_classes: 4 };
    let model = Arc::new(random_model(s, 42));
    let router = Router::builder()
        .shape(s)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(1))
        .fault_plan(FaultPlan::parse("cpu:fail_after=3").unwrap())
        .engine(Box::new(CpuSingleEngine::new(Arc::clone(&model))))
        .engine(Box::new(CpuMultiEngine::new(Arc::clone(&model), 2)))
        .build()
        .unwrap();

    let info = router.open_session(Precision::F32).unwrap();
    assert_eq!(info.target, "cpu", "session pins to the first f32 stream pool");

    // Concurrent batched traffic against the same (dying) primary: it
    // must keep resolving via failover while the stream migrates.
    let bg = {
        let router = router.clone();
        let w = window(s, 9);
        std::thread::spawn(move || {
            for _ in 0..6 {
                router.classify(w.clone()).expect("classify must fail over, not die");
                std::thread::sleep(Duration::from_millis(2));
            }
        })
    };

    let mut oracle = StreamState::new(s);
    let steps_per_chunk = 2;
    for chunk in 0..8 {
        let frames: Vec<f32> = (0..steps_per_chunk * s.input_dim)
            .map(|j| ((chunk * 31 + j * 7) % 97) as f32 / 97.0 - 0.5)
            .collect();
        let reply = router.classify_stream(info.id, frames.clone(), None).unwrap();
        let expect = model.stream_chunk(&frames, steps_per_chunk, &mut oracle);
        assert_eq!(reply.logits, expect, "chunk {chunk} logits drifted across migration");
    }
    bg.join().unwrap();

    let m = &router.metrics;
    assert_eq!(
        m.sessions_migrated.load(Ordering::Relaxed),
        1,
        "exactly one migration per pool death"
    );
    assert_eq!(router.close_session(info.id).unwrap(), 16);
}

// ---- watchdog: hung dispatch is reclaimed, not waited out ------------

/// A hang on the primary is bounded by the watchdog: the batch fails
/// over mid-hang, the breaker force-opens, and the stolen dispatch's
/// gauges drain when the sleeper wakes.
#[test]
fn watchdog_reclaims_hung_dispatch_and_fails_over() {
    let s = shape();
    let router = Router::builder()
        .shape(s)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(1))
        .watchdog(Duration::from_millis(100))
        .fault_plan(FaultPlan::parse("cpu:hang_after=0,hang_ms=1000").unwrap())
        .engine(Box::new(StubEngine::new(Target::CpuSingle, s.num_classes)))
        .engine(Box::new(StubEngine::new(Target::CpuMulti(2), s.num_classes)))
        .build()
        .unwrap();

    let t0 = Instant::now();
    let reply = router.classify(window(s, 0)).unwrap();
    assert_eq!(reply.target, "cpu-multi", "reclaimed batch must land on the healthy pool");
    assert!(
        t0.elapsed() < Duration::from_millis(900),
        "the reply must beat the 1 s hang — watchdog, not patience"
    );

    let m = &router.metrics;
    assert_eq!(m.watchdog_fired.load(Ordering::Relaxed), 1);
    assert!(m.breaker_open.load(Ordering::Relaxed) >= 1, "wedged pool force-opens");
    // The hung worker wakes at 300 ms and finds its slot already stolen.
    assert_inflight_drains(&router);
}

// ---- brownout: degraded int8 service over both live servers ----------

fn brownout_router() -> Router {
    let s = shape();
    Router::builder()
        .shape(s)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(1))
        .breaker(2, Duration::from_secs(30))
        .fault_plan(FaultPlan::parse("cpu:fail_rate=1").unwrap())
        .engine(Box::new(StubEngine::new(Target::CpuSingle, s.num_classes)))
        .engine(Box::new(StubEngine::new(Target::CpuQuant, s.num_classes)))
        .build()
        .unwrap()
}

fn classify_req(id: u64, s: ModelShape, allow_degraded: bool) -> Request {
    Request::Classify {
        id: Some(id),
        window: window(s, id as usize),
        target: None,
        precision: None,
        deadline_ms: None,
        allow_degraded,
    }
}

/// JSON transport: once the only f32 pool's breaker opens, an opted-in
/// request is served from the int8 tier and marked `degraded:"int8"`;
/// a non-opted request sheds typed.
#[test]
fn brownout_degrades_opted_requests_over_tcp_json() {
    let s = shape();
    let srv = Server::bind("127.0.0.1:0", brownout_router()).unwrap();
    let mut client = Client::connect(srv.addr()).unwrap();

    // Two injected failures trip the f32 breaker open.
    for i in 0..2 {
        match client.call(&classify_req(i, s, false)).unwrap() {
            Response::Error { code, .. } => assert_eq!(code.as_str(), "engine"),
            other => panic!("expected injected failure, got {other:?}"),
        }
    }

    // Opted in: degraded int8 service instead of shed.
    match client.call(&classify_req(2, s, true)).unwrap() {
        Response::Result { outcome, .. } => {
            assert_eq!(outcome.degraded.as_deref(), Some("int8"));
            assert_eq!(outcome.target, "cpu-quant");
            assert_eq!(outcome.class, 1);
        }
        other => panic!("expected degraded result, got {other:?}"),
    }

    // Not opted in: typed shed, never a silent int8 answer.
    match client.call(&classify_req(3, s, false)).unwrap() {
        Response::Error { code, .. } => assert_eq!(code.as_str(), "overloaded"),
        other => panic!("expected typed shed, got {other:?}"),
    }
}

/// The same brownout contract over the event-driven server and the v3
/// binary frame codec — `allow_degraded` and `degraded` both survive
/// the binary round trip.
#[test]
fn brownout_degrades_opted_requests_over_event_binary() {
    let s = shape();
    let router = brownout_router();
    let metrics = Arc::clone(&router.metrics);
    let srv = EventServer::bind("127.0.0.1:0", router).unwrap();
    let mut client = Client::connect(srv.addr()).unwrap();
    client.negotiate_binary().unwrap();

    for i in 0..2 {
        match client.call(&classify_req(i, s, false)).unwrap() {
            Response::Error { code, .. } => assert_eq!(code.as_str(), "engine"),
            other => panic!("expected injected failure, got {other:?}"),
        }
    }
    match client.call(&classify_req(2, s, true)).unwrap() {
        Response::Result { outcome, .. } => {
            assert_eq!(outcome.degraded.as_deref(), Some("int8"));
            assert_eq!(outcome.target, "cpu-quant");
        }
        other => panic!("expected degraded result, got {other:?}"),
    }
    assert_eq!(metrics.degraded.load(Ordering::Relaxed), 1);
    assert_eq!(metrics.breaker_open.load(Ordering::Relaxed), 1);
}
