//! Wire-protocol integration: protocol v3 (binary frames) must be
//! indistinguishable from protocol v2 (JSON lines) in everything but
//! cost, over BOTH serving front-ends — the thread-per-connection
//! server and the event-driven multiplexer (DESIGN.md §12). Also
//! drives the frame robustness rules over live sockets: header-level
//! garbage kills a connection, malformed payloads get typed errors,
//! and neither takes the server down.
//!
//! Artifact-free: engines run the shared random-weight fixture, so the
//! parity checks are deterministic and run on every host.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use mobirnn::bench::random_model;
use mobirnn::config::ModelShape;
use mobirnn::coordinator::{CpuSingleEngine, OffloadPolicy, Router};
use mobirnn::server::{frame, Client, ClassifyOutcome, EventServer, Request, Response, Server};
use mobirnn::simulator::Target;

fn shape() -> ModelShape {
    ModelShape { num_layers: 1, hidden: 16, input_dim: 3, seq_len: 10, num_classes: 6 }
}

/// A deterministic single-engine router: same weights, same policy,
/// batch size 1 — so both transports must produce identical outcomes.
fn router() -> Router {
    let model = Arc::new(random_model(shape(), 42));
    Router::builder()
        .shape(shape())
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(1))
        .engine(Box::new(CpuSingleEngine::new(model)))
        .build()
        .unwrap()
}

fn window(i: usize) -> Vec<f32> {
    let n = shape().seq_len * shape().input_dim;
    (0..n).map(|j| ((i * 31 + j * 7) % 97) as f32 / 97.0 - 0.5).collect()
}

fn assert_same_outcome(json: &ClassifyOutcome, binary: &ClassifyOutcome) {
    assert_eq!(json.class, binary.class, "class must match across transports");
    assert_eq!(json.label, binary.label, "label must match across transports");
    assert_eq!(json.target, binary.target, "target must match across transports");
    assert_eq!(json.batch_size, binary.batch_size, "batch size must match across transports");
}

/// Run the full op catalogue twice against `addr` — once over JSON,
/// once over binary frames — and require identical results.
fn parity_against(addr: SocketAddr) {
    let mut json = Client::connect(addr).unwrap();
    let mut bin = Client::connect(addr).unwrap();
    bin.negotiate_binary().unwrap();

    json.ping().unwrap();
    bin.ping().unwrap();

    // classify: identical class, label, target, batch size.
    for i in 0..4 {
        let a = json.classify(&window(i), i as u64).unwrap();
        let b = bin.classify(&window(i), i as u64).unwrap();
        assert_same_outcome(&a, &b);
    }

    // classify_batch: same outcomes element-wise.
    let req = Request::ClassifyBatch { id: Some(9), windows: vec![window(0), window(1)] };
    let (a, b) = (json.call(&req).unwrap(), bin.call(&req).unwrap());
    match (a, b) {
        (
            Response::BatchResult { outcomes: oa, .. },
            Response::BatchResult { outcomes: ob, .. },
        ) => {
            assert_eq!(oa.len(), 2);
            assert_eq!(ob.len(), 2);
            for (x, y) in oa.iter().zip(ob.iter()) {
                assert_same_outcome(x, y);
            }
        }
        other => panic!("expected two batch_results, got {other:?}"),
    }

    // sessions: same per-step classes AND bit-identical logits — the
    // JSON float formatter is shortest-roundtrip, so nothing may drift.
    let frames: Vec<f32> = (0..3 * shape().input_dim).map(|j| j as f32 / 10.0).collect();
    let sa = json.open_session(None).unwrap();
    let sb = bin.open_session(None).unwrap();
    let (ca, la) = json.classify_stream(sa, &frames, 1).unwrap();
    let (cb, lb) = bin.classify_stream(sb, &frames, 1).unwrap();
    assert_eq!(ca, cb, "stream classes must match across transports");
    assert_eq!(la, lb, "stream logits must match bit-for-bit");
    assert_eq!(json.close_session(sa).unwrap(), 3);
    assert_eq!(bin.close_session(sb).unwrap(), 3);

    // set_load / stats: same knobs visible over both.
    json.set_load(0.25, 0.5).unwrap();
    let (g_json, c_json, _) = json.stats().unwrap();
    let (g_bin, c_bin, _) = bin.stats().unwrap();
    assert!((g_json - 0.25).abs() < 1e-9 && (g_bin - 0.25).abs() < 1e-9);
    assert!((c_json - 0.5).abs() < 1e-9 && (c_bin - 0.5).abs() < 1e-9);

    // errors: the same bad request earns the same typed code.
    let bad = Request::Classify {
        id: Some(13),
        window: vec![0.0; 5],
        target: None,
        precision: None,
        deadline_ms: None,
        allow_degraded: false,
    };
    let (a, b) = (json.call(&bad).unwrap(), bin.call(&bad).unwrap());
    match (a, b) {
        (Response::Error { code: ca, .. }, Response::Error { code: cb, .. }) => {
            assert_eq!(ca, cb, "error codes must match across transports");
        }
        other => panic!("expected matching typed errors, got {other:?}"),
    }

    json.quit().unwrap();
    bin.quit().unwrap();
}

#[test]
fn every_op_matches_across_transports_threaded() {
    let srv = Server::bind("127.0.0.1:0", router()).unwrap();
    parity_against(srv.addr());
}

#[test]
fn every_op_matches_across_transports_event() {
    let srv = EventServer::bind("127.0.0.1:0", router()).unwrap();
    parity_against(srv.addr());
}

/// Upgrade a raw socket to binary frames by hand, for byte-level abuse
/// the typed [`Client`] refuses to send.
fn upgrade_raw(addr: SocketAddr) -> (BufReader<TcpStream>, TcpStream) {
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    writer.write_all(b"{\"type\":\"hello\",\"proto\":3}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("hello_ok"), "{line}");
    (reader, writer)
}

fn read_raw_frame(reader: &mut BufReader<TcpStream>) -> std::io::Result<Response> {
    let mut header = [0u8; frame::HEADER_LEN];
    reader.read_exact(&mut header)?;
    let h = frame::parse_header(&header).expect("well-formed reply header");
    let mut payload = vec![0u8; h.payload_len as usize];
    reader.read_exact(&mut payload)?;
    Ok(frame::decode_response_body(&h, &payload).expect("well-formed reply payload"))
}

/// Abuse one server at the byte level; it must answer typed errors for
/// malformed payloads, close on lost framing, and never stop serving.
fn abuse(addr: SocketAddr, kind: &str) {
    // Malformed payload under a valid header: typed error, the
    // connection survives and still answers pings.
    let (mut reader, mut writer) = upgrade_raw(addr);
    let payload = 99u32.to_le_bytes(); // classify claiming 99 floats, sending none
    let mut bad = vec![frame::MAGIC, frame::FRAME_VERSION, 0x05, 0];
    bad.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    bad.extend_from_slice(&0u64.to_le_bytes());
    bad.extend_from_slice(&payload);
    writer.write_all(&bad).unwrap();
    match read_raw_frame(&mut reader).unwrap() {
        Response::Error { code, .. } => assert_eq!(code.as_str(), "bad_request", "{kind}"),
        other => panic!("{kind}: expected typed error, got {other:?}"),
    }
    writer.write_all(&frame::encode_request(&Request::Ping)).unwrap();
    assert_eq!(read_raw_frame(&mut reader).unwrap(), Response::Pong, "{kind}");

    // Garbage where a header should be: framing is lost, the
    // connection closes (EOF, not a hang and not a panic).
    let (mut reader, mut writer) = upgrade_raw(addr);
    writer.write_all(b"GET / HTTP/1.1\r\n\r\n").unwrap();
    assert!(read_raw_frame(&mut reader).is_err(), "{kind}: garbage must close");

    // An oversized length closes before any allocation happens.
    let (mut reader, mut writer) = upgrade_raw(addr);
    let mut huge = vec![frame::MAGIC, frame::FRAME_VERSION, 0x01, 0];
    huge.extend_from_slice(&u32::MAX.to_le_bytes());
    huge.extend_from_slice(&0u64.to_le_bytes());
    writer.write_all(&huge).unwrap();
    assert!(read_raw_frame(&mut reader).is_err(), "{kind}: oversized must close");

    // Mid-frame disconnect: three header bytes, then gone.
    let (reader, mut writer) = upgrade_raw(addr);
    writer.write_all(&[frame::MAGIC, frame::FRAME_VERSION, 0x05]).unwrap();
    drop(writer);
    drop(reader);

    // After all of that, the server still serves new clients.
    let mut client = Client::connect(addr).unwrap();
    client.ping().unwrap();
    client.quit().unwrap();
}

#[test]
fn frame_abuse_over_live_sockets_threaded() {
    let srv = Server::bind("127.0.0.1:0", router()).unwrap();
    abuse(srv.addr(), "threaded");
}

#[test]
fn frame_abuse_over_live_sockets_event() {
    let srv = EventServer::bind("127.0.0.1:0", router()).unwrap();
    abuse(srv.addr(), "event");
}

#[test]
fn event_server_multiplexes_mixed_transports() {
    let mut srv = EventServer::builder()
        .io_threads(2)
        .max_connections(128)
        .bind("127.0.0.1:0", router())
        .unwrap();
    let mut clients: Vec<Client> = (0..96).map(|_| Client::connect(srv.addr()).unwrap()).collect();
    for (i, c) in clients.iter_mut().enumerate() {
        if i % 2 == 0 {
            c.negotiate_binary().unwrap();
        }
    }
    // Everybody gets served, interleaved, on two I/O threads.
    let mut first = None;
    for (i, c) in clients.iter_mut().enumerate() {
        let outcome = c.classify(&window(i % 7), i as u64).unwrap();
        let class = *first.get_or_insert(outcome.class);
        if i % 7 == 0 {
            assert_eq!(outcome.class, class, "same window, same class, any transport");
        }
    }
    assert_eq!(srv.connections_accepted(), 96);
    drop(clients);
    srv.stop();
}
