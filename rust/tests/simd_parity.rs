//! SIMD ↔ scalar parity property tests (DESIGN.md §13).
//!
//! The dispatched kernels (whatever `kernel::active()` resolved to on
//! this host) are swept against the scalar oracles across M/K/N grids
//! that cover every remainder path: quad/duo/single M tails, K not a
//! multiple of the f32 quad (or the int8 vector width), and N tails
//! shorter than one vector register.
//!
//! Contracts under test:
//! - **int8**: BIT-EXACT across ISAs. Integer adds are associative, so
//!   any lane blocking must produce identical i32 accumulators.
//! - **f32**: within the documented absolute bound (§13: ≤ 2e-4 for
//!   inputs in [-1, 1] at K ≤ 128, which covers the sweeps here) of the
//!   scalar oracle.
//!   SIMD fuses multiply-adds; scalar never fuses — bit equality is the
//!   contract for the scalar path only.
//! - **within one ISA**: `matmul_into` ≡ m independent `gemv_into` calls
//!   bit-for-bit — the invariant the batched/streaming parity guarantees
//!   stand on.
//!
//! Under the scalar-forced CI lane (`MOBIRNN_FORCE_SCALAR=1`) the
//! dispatched side IS the scalar oracle and these tests pass trivially —
//! by design: that lane exists to exercise the fallback everywhere else.

use mobirnn::lstm::quant::{quant_matmul_into, quant_matmul_into_scalar, PackedQuantMatrix};
use mobirnn::tensor::{gemv_into, gemv_into_scalar, matmul_into, matmul_into_scalar};
use mobirnn::util::Rng;

/// Documented f32 SIMD-vs-scalar absolute tolerance (DESIGN.md §13).
const F32_ABS_TOL: f32 = 2e-4;

const M_SWEEP: &[usize] = &[1, 2, 3, 4, 5, 6, 7, 8, 9];
const K_SWEEP: &[usize] = &[1, 2, 3, 4, 5, 8, 9, 31, 32, 33, 63, 64, 65];
const N_SWEEP: &[usize] = &[1, 3, 7, 8, 9, 15, 16, 17, 128];

fn fill_uniform(rng: &mut Rng, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.uniform(-1.0, 1.0)).collect()
}

#[test]
fn f32_matmul_dispatched_within_documented_bound_of_scalar() {
    let mut rng = Rng::new(0xA11CE);
    for &m in M_SWEEP {
        for &k in K_SWEEP {
            for &n in N_SWEEP {
                let a = fill_uniform(&mut rng, m * k);
                let w = fill_uniform(&mut rng, k * n);
                // Non-zero init: the kernels accumulate into `out`.
                let init = fill_uniform(&mut rng, m * n);
                let mut got = init.clone();
                let mut want = init.clone();
                matmul_into(&mut got, &a, &w, m, k, n);
                matmul_into_scalar(&mut want, &a, &w, m, k, n);
                for (i, (g, e)) in got.iter().zip(&want).enumerate() {
                    assert!(
                        (g - e).abs() <= F32_ABS_TOL,
                        "({m},{k},{n}) out[{i}]: dispatched {g} vs scalar {e}"
                    );
                }
            }
        }
    }
}

#[test]
fn f32_gemv_dispatched_within_documented_bound_of_scalar() {
    let mut rng = Rng::new(0xB0B);
    for &k in K_SWEEP {
        for &n in N_SWEEP {
            let v = fill_uniform(&mut rng, k);
            let w = fill_uniform(&mut rng, k * n);
            let init = fill_uniform(&mut rng, n);
            let mut got = init.clone();
            let mut want = init.clone();
            gemv_into(&mut got, &w, &v);
            gemv_into_scalar(&mut want, &w, &v);
            for (i, (g, e)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - e).abs() <= F32_ABS_TOL,
                    "({k},{n}) acc[{i}]: dispatched {g} vs scalar {e}"
                );
            }
        }
    }
}

#[test]
fn f32_matmul_is_bitwise_m_gemvs_on_the_active_isa() {
    // The per-ISA invariant every batched↔per-window parity guarantee
    // rests on: whatever M-blocking the active kernel uses, each row's
    // per-element accumulation chain must equal the GEMV path exactly.
    let mut rng = Rng::new(0xC0FFEE);
    for &m in M_SWEEP {
        for &k in K_SWEEP {
            for &n in N_SWEEP {
                let a = fill_uniform(&mut rng, m * k);
                let w = fill_uniform(&mut rng, k * n);
                let init = fill_uniform(&mut rng, m * n);
                let mut got = init.clone();
                matmul_into(&mut got, &a, &w, m, k, n);
                let mut want = init;
                for (row, acc) in a.chunks_exact(k).zip(want.chunks_exact_mut(n)) {
                    gemv_into(acc, &w, row);
                }
                assert_eq!(got, want, "({m},{k},{n})");
            }
        }
    }
}

/// Random `[m, k_padded]` int8 activations with the padding lanes
/// (`i % k_padded >= k`) zeroed — the same layout `quantize_activations`
/// produces.
fn random_activations(rng: &mut Rng, m: usize, k: usize, kp: usize) -> Vec<i8> {
    (0..m * kp)
        .map(|i| if i % kp >= k { 0 } else { rng.uniform(-127.0, 127.0) as i8 })
        .collect()
}

#[test]
fn int8_matmul_dispatched_is_bit_exact_with_scalar() {
    let mut rng = Rng::new(0xDEAD);
    for &m in M_SWEEP {
        for &k in K_SWEEP {
            for &n in N_SWEEP {
                let w = fill_uniform(&mut rng, k * n);
                let wq = PackedQuantMatrix::pack(&w, k, n);
                let kp = k.div_ceil(4) * 4;
                let a = random_activations(&mut rng, m, k, kp);
                let mut got = vec![0i32; m * n];
                let mut want = vec![0i32; m * n];
                quant_matmul_into(&mut got, &a, &wq, m);
                quant_matmul_into_scalar(&mut want, &a, &wq, m);
                assert_eq!(got, want, "({m},{k},{n})");
            }
        }
    }
}
