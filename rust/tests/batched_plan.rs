//! Golden parity and chunking properties for the batched time-major
//! execution plan (DESIGN.md §8) — artifact-free, runs on every CI.
//!
//! The batched plan re-orders the LOOPS of the native forward pass, not
//! its arithmetic: per output element it performs the exact same float
//! operations in the same order as the per-window oracle, so parity here
//! is asserted BIT-FOR-BIT, not within a tolerance. If a future kernel
//! change re-associates the accumulation (SIMD, different blocking),
//! relax these to a 1e-6 max-abs-diff envelope — consciously, in the
//! same commit that changes the summation order.

use std::sync::Arc;

use mobirnn::bench::random_model;
use mobirnn::config::ModelShape;
use mobirnn::lstm::model::InferenceState;
use mobirnn::lstm::{BatchArena, ThreadedLstm};
use mobirnn::tensor::Tensor;
use mobirnn::util::Rng;

fn random_windows(shape: ModelShape, batch: usize, rng: &mut Rng) -> Tensor {
    let n = batch * shape.seq_len * shape.input_dim;
    let data: Vec<f32> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
    Tensor::new(vec![batch, shape.seq_len, shape.input_dim], data)
}

#[test]
fn batched_plan_matches_per_window_oracle_bit_for_bit() {
    // Shapes chosen to exercise every kernel path: quad-M main blocks
    // (B=8), M remainders (B=1, 3), quad-K remainders (I=3, 5; H=17),
    // single layer, deep stacks, and the paper-default 2l/32h.
    let shapes = [
        ModelShape { num_layers: 1, hidden: 8, input_dim: 3, seq_len: 5, num_classes: 4 },
        ModelShape { num_layers: 2, hidden: 32, input_dim: 9, seq_len: 16, num_classes: 6 },
        ModelShape { num_layers: 3, hidden: 17, input_dim: 5, seq_len: 7, num_classes: 3 },
    ];
    for (si, &shape) in shapes.iter().enumerate() {
        let model = random_model(shape, 100 + si as u64);
        let mut st = InferenceState::new(shape);
        let mut arena = BatchArena::new(shape);
        let mut rng = Rng::new(200 + si as u64);
        for &b in &[1usize, 3, 8] {
            let x = random_windows(shape, b, &mut rng);
            let batched = model.forward_batch(&x, &mut arena);
            assert_eq!(batched.shape(), &[b, shape.num_classes]);
            for i in 0..b {
                let oracle = model.forward_window(x.slab(i), &mut st);
                assert_eq!(
                    batched.row(i),
                    &oracle[..],
                    "shape #{si} {shape:?} B={b}: batched row {i} != per-window oracle"
                );
            }
        }
    }
}

#[test]
fn batched_plan_handles_zero_padding_windows() {
    // The batcher pads short batches with all-zero windows; the plan
    // must produce the same logits for a zero window as the oracle and
    // not disturb its neighbours.
    let shape = ModelShape { num_layers: 2, hidden: 32, input_dim: 9, seq_len: 16, num_classes: 6 };
    let model = random_model(shape, 7);
    let mut rng = Rng::new(8);
    let real = random_windows(shape, 2, &mut rng);
    let window_len = shape.seq_len * shape.input_dim;
    let mut padded = real.data().to_vec();
    padded.resize(4 * window_len, 0.0);
    let x = Tensor::new(vec![4, shape.seq_len, shape.input_dim], padded);
    let mut arena = BatchArena::new(shape);
    let batched = model.forward_batch(&x, &mut arena);
    let mut st = InferenceState::new(shape);
    for i in 0..4 {
        let oracle = model.forward_window(x.slab(i), &mut st);
        assert_eq!(batched.row(i), &oracle[..], "row {i} (rows 2/3 are zero padding)");
    }
}

#[test]
fn prop_threaded_chunking_preserves_order_and_equality() {
    // Random batch sizes x thread counts x chunk sizes: the chunked pool
    // must return exactly the per-window oracle's logits, in input
    // order, for EVERY chunking. Failure messages carry the full case.
    let shape = ModelShape { num_layers: 2, hidden: 8, input_dim: 3, seq_len: 6, num_classes: 4 };
    let model = Arc::new(random_model(shape, 31));
    let mut rng = Rng::new(32);
    let mut st = InferenceState::new(shape);
    for case in 0..25 {
        let batch = 1 + rng.below(13) as usize;
        let x = random_windows(shape, batch, &mut rng);
        let mut expected = Vec::with_capacity(batch * shape.num_classes);
        for i in 0..batch {
            expected.extend(model.forward_window(x.slab(i), &mut st));
        }
        let expected = Tensor::new(vec![batch, shape.num_classes], expected);

        let threads = 1 + rng.below(4) as usize;
        let pool = ThreadedLstm::new(Arc::clone(&model), threads);
        // Chunk sizes from 1 (one row per job) past the batch size
        // (single job), plus the default policy.
        let chunk = 1 + rng.below(batch as u64 + 2) as usize;
        let got = pool.forward_batch_chunked(&x, chunk);
        assert_eq!(got, expected, "case {case}: batch={batch} threads={threads} chunk={chunk}");
        let got_default = pool.forward_batch(&x);
        assert_eq!(got_default, expected, "case {case}: default chunking, threads={threads}");
        assert_eq!(pool.windows_completed(), 2 * batch, "case {case}: row accounting");
    }
}
