//! End-to-end serving integration: TCP server + concurrent clients +
//! load knobs, against the real trained artifacts, through the typed
//! protocol-v2 client.

use std::sync::Arc;
use std::time::Duration;

use mobirnn::config::Manifest;
use mobirnn::coordinator::{DeviceState, OffloadPolicy, Router};
use mobirnn::har;
use mobirnn::runtime::Runtime;
use mobirnn::server::{Client, Request, Response, Server};
use mobirnn::simulator::DeviceProfile;

fn start_server(policy: OffloadPolicy) -> Option<(Server, DeviceState)> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    let man = Manifest::load(dir).unwrap();
    let rt = Runtime::start(&man).unwrap();
    let device = DeviceState::new(DeviceProfile::nexus5());
    let router = Router::builder()
        .policy(policy)
        .device(device.clone())
        .max_wait(Duration::from_millis(1))
        .manifest(&man, rt)
        .unwrap()
        .build()
        .unwrap();
    Some((Server::bind("127.0.0.1:0", router).unwrap(), device))
}

#[test]
fn end_to_end_accuracy_over_tcp() {
    let Some((srv, _)) = start_server(OffloadPolicy::CostModel) else { return };
    // Use the python-generated artifact test set so accuracy is
    // comparable to the manifest's train_report.
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let man = Manifest::load(&dir).unwrap();
    let ds = har::HarDataset::load(man.path(&man.har_test.file)).unwrap();

    let mut client = Client::connect(srv.addr()).unwrap();
    let n = 64;
    let mut correct = 0;
    for i in 0..n {
        let outcome = client.classify(ds.window(i), i as u64).unwrap();
        if outcome.class == ds.labels[i] as usize {
            correct += 1;
        }
        assert!(outcome.sim_latency_us > 0.0);
    }
    let acc = correct as f64 / n as f64;
    assert!(acc > 0.6, "TCP-served accuracy {acc} too low (train report says ~0.8)");
}

#[test]
fn concurrent_clients_get_batched() {
    let Some((srv, _)) = start_server(OffloadPolicy::CostModel) else { return };
    let ds = Arc::new(har::generate(32, 5));
    let addr = srv.addr();
    let handles: Vec<_> = (0..8)
        .map(|c| {
            let ds = Arc::clone(&ds);
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                for i in 0..4 {
                    let idx = c * 4 + i;
                    let outcome = client.classify(ds.window(idx), idx as u64).unwrap();
                    assert!(outcome.class < har::NUM_CLASSES);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // Ask the server for its stats and check batching happened.
    let mut client = Client::connect(addr).unwrap();
    let (_, _, metrics) = client.stats().unwrap();
    let requests = metrics.get("requests").as_usize().unwrap();
    let batches = metrics.get("batches").as_usize().unwrap();
    assert_eq!(requests, 32);
    assert!(batches <= requests);
    assert!(metrics.get("mean_batch_size").as_f64().unwrap() >= 1.0);
    // The pipelined-dispatch counters surface on the wire and stayed
    // clean under this well-behaved load.
    assert_eq!(metrics.get("shed").as_usize(), Some(0));
    assert_eq!(metrics.get("expired").as_usize(), Some(0));
    assert_eq!(metrics.get("queue_depth").as_usize(), Some(0));
    assert!(metrics.get("inflight").get("gpu").as_usize().is_some());
}

#[test]
fn batch_request_serves_all_windows_in_one_round_trip() {
    let Some((srv, _)) = start_server(OffloadPolicy::CostModel) else { return };
    let ds = har::generate(4, 7);
    let mut client = Client::connect(srv.addr()).unwrap();
    let windows: Vec<Vec<f32>> = (0..4).map(|i| ds.window(i).to_vec()).collect();
    match client.call(&Request::ClassifyBatch { id: Some(1), windows }).unwrap() {
        Response::BatchResult { id, outcomes } => {
            assert_eq!(id, Some(1));
            assert_eq!(outcomes.len(), 4);
            for o in &outcomes {
                assert!(o.class < har::NUM_CLASSES);
                assert!(o.sim_latency_us > 0.0);
            }
        }
        other => panic!("expected batch_result, got {other:?}"),
    }
}

#[test]
fn load_knob_flips_offload_target_live() {
    let Some((srv, _device)) = start_server(OffloadPolicy::CostModel) else { return };
    let ds = har::generate(2, 9);
    let mut client = Client::connect(srv.addr()).unwrap();

    // Idle: GPU.
    let outcome = client.classify(ds.window(0), 0).unwrap();
    assert_eq!(outcome.target, "gpu");

    // Saturate the device via the wire protocol, like a co-running game.
    client.set_load(0.9, 0.9).unwrap();
    let outcome = client.classify(ds.window(1), 1).unwrap();
    assert_ne!(outcome.target, "gpu", "§4.5: high load must steer off the GPU");

    // Out-of-range load is rejected with a typed error and not applied.
    let err = client.set_load(7.0, 0.0).unwrap_err().to_string();
    assert!(err.contains("invalid_load"), "{err}");

    // And back.
    client.set_load(0.0, 0.0).unwrap();
    let outcome = client.classify(ds.window(0), 2).unwrap();
    assert_eq!(outcome.target, "gpu");
}

#[test]
fn per_request_override_over_the_wire() {
    let Some((srv, _)) = start_server(OffloadPolicy::CostModel) else { return };
    let ds = har::generate(1, 11);
    let mut client = Client::connect(srv.addr()).unwrap();
    // Idle device: the policy would pick the GPU; the wire override pins
    // this request to the single-thread CPU engine.
    let req = Request::Classify {
        id: Some(3),
        window: ds.window(0).to_vec(),
        target: Some(mobirnn::simulator::Target::CpuSingle),
        precision: None,
        deadline_ms: None,
        allow_degraded: false,
    };
    match client.call(&req).unwrap() {
        Response::Result { id, outcome } => {
            assert_eq!(id, Some(3));
            assert_eq!(outcome.target, "cpu", "wire target override must be honored");
        }
        other => panic!("expected result, got {other:?}"),
    }
}

#[test]
fn fine_policy_reports_higher_sim_latency() {
    // The CUDA-style policy must be visibly worse in the served
    // simulated latencies (Fig 3, live).
    let Some((coarse_srv, _)) = start_server(OffloadPolicy::parse("gpu").unwrap()) else {
        return;
    };
    let Some((fine_srv, _)) = start_server(OffloadPolicy::parse("fine").unwrap()) else { return };
    let ds = har::generate(3, 21);
    let mut c1 = Client::connect(coarse_srv.addr()).unwrap();
    let mut c2 = Client::connect(fine_srv.addr()).unwrap();
    for i in 0..3 {
        let coarse_us = c1.classify(ds.window(i), i as u64).unwrap().sim_latency_us;
        let fine_us = c2.classify(ds.window(i), i as u64).unwrap().sim_latency_us;
        assert!(
            fine_us > 5.0 * coarse_us,
            "fine {fine_us}µs should dwarf coarse {coarse_us}µs"
        );
    }
}

#[test]
fn malformed_traffic_does_not_kill_server() {
    let Some((srv, _)) = start_server(OffloadPolicy::CostModel) else { return };
    use std::io::{BufRead, BufReader, Write};
    let mut s = std::net::TcpStream::connect(srv.addr()).unwrap();
    s.write_all(b"garbage\n{\"type\":\"nope\"}\n\n").unwrap();
    let mut reader = BufReader::new(s.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"));
    assert!(line.contains("bad_json"), "typed error code on the wire: {line}");
    // Server still answers a well-formed request on a fresh connection.
    let ds = har::generate(1, 33);
    let mut client = Client::connect(srv.addr()).unwrap();
    let outcome = client.classify(ds.window(0), 0).unwrap();
    assert!(outcome.class < har::NUM_CLASSES);
}
