//! Streaming-session integration (DESIGN.md §11): incremental parity
//! against the batched plan, TTL eviction, and session-affine
//! scheduling with explicit failover migration — all over live routers
//! on the artifact-free random-weight fixture.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use mobirnn::bench::random_model;
use mobirnn::config::ModelShape;
use mobirnn::coordinator::{
    CpuQuantEngine, CpuSingleEngine, Engine, OffloadPolicy, Precision, Router, ServeError,
};
use mobirnn::lstm::{BatchArena, StreamState};
use mobirnn::simulator::Target;
use mobirnn::tensor::Tensor;

fn shape() -> ModelShape {
    ModelShape { num_layers: 2, hidden: 8, input_dim: 3, seq_len: 12, num_classes: 4 }
}

/// Deterministic window fixture, flat `[T, I]`.
fn window(shape: ModelShape, seed: usize) -> Vec<f32> {
    let n = shape.seq_len * shape.input_dim;
    (0..n).map(|j| ((seed * 131 + j * 17) % 101) as f32 / 101.0 - 0.5).collect()
}

/// The same trained weights at a different `seq_len`: `random_model`'s
/// RNG consumption depends only on the layer dims, so re-seeding with a
/// reshaped `seq_len` yields the identical model truncated to `t` steps.
fn model_at_seq_len(base: ModelShape, t: usize, seed: u64) -> mobirnn::lstm::LstmModel {
    random_model(ModelShape { seq_len: t, ..base }, seed)
}

// ---- incremental parity (the tentpole's correctness contract) --------

/// T single-step `stream_chunk` calls from a fresh state produce, at
/// every step t, logits bit-for-bit equal to running the first t+1
/// frames through the batched `forward_rows` plan.
#[test]
fn f32_stream_matches_batched_plan_bit_for_bit_at_every_prefix() {
    let s = shape();
    let model = random_model(s, 7);
    let w = window(s, 1);
    let mut state = StreamState::new(s);
    for t in 0..s.seq_len {
        let frame = &w[t * s.input_dim..(t + 1) * s.input_dim];
        let step_logits = model.stream_step(frame, &mut state);
        assert_eq!(step_logits.len(), s.num_classes);

        let prefix_model = model_at_seq_len(s, t + 1, 7);
        let mut arena = BatchArena::new(prefix_model.shape);
        let batched =
            prefix_model.forward_rows(&w[..(t + 1) * s.input_dim], 1, &mut arena);
        assert_eq!(step_logits, batched, "prefix of {} steps diverged", t + 1);
    }
    assert_eq!(state.steps(), s.seq_len as u64);

    // The persisted planes equal what one whole-window pass accumulates:
    // streaming the same window into a fresh state must reproduce them.
    let mut replay = StreamState::new(s);
    let _ = model.stream_chunk(&w, s.seq_len, &mut replay);
    for li in 0..s.num_layers {
        assert_eq!(state.h_plane(li), replay.h_plane(li));
        assert_eq!(state.c_plane(li), replay.c_plane(li));
    }
}

/// Chunking is irrelevant to the numbers: 1+1+…+1, one T-chunk, and a
/// ragged 5+4+3 split all visit the identical accumulation sequence.
#[test]
fn f32_chunking_never_changes_logits_or_state() {
    let s = shape();
    let model = random_model(s, 9);
    let w = window(s, 2);

    let mut whole = StreamState::new(s);
    let whole_logits = model.stream_chunk(&w, s.seq_len, &mut whole);

    let mut stepped = StreamState::new(s);
    let mut stepped_logits = Vec::new();
    for t in 0..s.seq_len {
        stepped_logits
            .extend(model.stream_step(&w[t * s.input_dim..(t + 1) * s.input_dim], &mut stepped));
    }

    let mut ragged = StreamState::new(s);
    let mut ragged_logits = Vec::new();
    let mut at = 0;
    for chunk in [5usize, 4, 3] {
        ragged_logits.extend(model.stream_chunk(
            &w[at * s.input_dim..(at + chunk) * s.input_dim],
            chunk,
            &mut ragged,
        ));
        at += chunk;
    }

    assert_eq!(whole_logits, stepped_logits);
    assert_eq!(whole_logits, ragged_logits);
    for li in 0..s.num_layers {
        assert_eq!(whole.h_plane(li), stepped.h_plane(li));
        assert_eq!(whole.c_plane(li), ragged.c_plane(li));
    }
}

/// Int8 mirror of the prefix-parity property: `stream_chunk_quant`
/// against `forward_rows_quant`, bit-for-bit. The h/c planes stay f32
/// (DESIGN.md §11), so the same [`StreamState`] drives both tiers.
#[test]
fn int8_stream_matches_batched_quant_plan_bit_for_bit_at_every_prefix() {
    let s = shape();
    let model = random_model(s, 11);
    let quant = model.quantize();
    let w = window(s, 3);
    let mut state = StreamState::new(s);
    for t in 0..s.seq_len {
        let frame = &w[t * s.input_dim..(t + 1) * s.input_dim];
        let step_logits = quant.stream_chunk_quant(frame, 1, &mut state);

        let prefix_quant = model_at_seq_len(s, t + 1, 11).quantize();
        let mut arena = BatchArena::new(prefix_quant.shape);
        let batched =
            prefix_quant.forward_rows_quant(&w[..(t + 1) * s.input_dim], 1, &mut arena);
        assert_eq!(step_logits, batched, "quant prefix of {} steps diverged", t + 1);
    }
    assert_eq!(state.steps(), s.seq_len as u64);
}

// ---- live-router round trips -----------------------------------------

fn f32_router(s: ModelShape) -> (Router, Arc<mobirnn::lstm::LstmModel>) {
    let model = Arc::new(random_model(s, 42));
    let router = Router::builder()
        .shape(s)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(1))
        .engine(Box::new(CpuSingleEngine::new(Arc::clone(&model))))
        .build()
        .unwrap();
    (router, model)
}

#[test]
fn live_router_stream_is_bit_for_bit_with_the_local_model() {
    let s = shape();
    let (router, model) = f32_router(s);
    let w = window(s, 4);

    let info = router.open_session(Precision::F32).unwrap();
    assert_eq!(info.target, "cpu");
    assert_eq!(router.metrics.sessions_open.load(Ordering::Relaxed), 1);

    let mut oracle = StreamState::new(s);
    for t in 0..s.seq_len {
        let frame = &w[t * s.input_dim..(t + 1) * s.input_dim];
        let reply = router.classify_stream(info.id, frame.to_vec(), Some(t as u64)).unwrap();
        assert_eq!(reply.id, Some(t as u64));
        assert_eq!(reply.steps, 1);
        assert_eq!(reply.target, "cpu");
        let expect = model.stream_step(frame, &mut oracle);
        assert_eq!(reply.logits, expect, "server state diverged at step {t}");
        assert_eq!(reply.classes.len(), 1);
    }

    assert_eq!(router.close_session(info.id).unwrap(), s.seq_len as u64);
    assert_eq!(router.metrics.sessions_open.load(Ordering::Relaxed), 0);
    // Closing again is the typed not-found error.
    let err = router.close_session(info.id).unwrap_err();
    assert!(matches!(
        err.downcast_ref::<ServeError>(),
        Some(ServeError::SessionNotFound(_))
    ));
}

#[test]
fn int8_sessions_pin_to_the_quant_pool_and_match_the_quant_model() {
    let s = shape();
    let model = Arc::new(random_model(s, 42));
    let quant = model.quantize();
    let router = Router::builder()
        .shape(s)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(1))
        .engine(Box::new(CpuSingleEngine::new(Arc::clone(&model))))
        .engine(Box::new(CpuQuantEngine::from_f32(&model)))
        .build()
        .unwrap();

    // f32 sessions never land on the quant pool (PR 4's precision
    // contract); int8 sessions pin there by construction.
    let f32_info = router.open_session(Precision::F32).unwrap();
    assert_eq!(f32_info.target, "cpu");
    let int8_info = router.open_session(Precision::Int8).unwrap();
    assert_eq!(int8_info.target, "cpu-quant");

    let w = window(s, 5);
    let mut oracle = StreamState::new(s);
    for t in 0..s.seq_len {
        let frame = &w[t * s.input_dim..(t + 1) * s.input_dim];
        let reply = router.classify_stream(int8_info.id, frame.to_vec(), None).unwrap();
        assert_eq!(reply.target, "cpu-quant", "int8 stream must stay on the quant pool");
        let expect = quant.stream_chunk_quant(frame, 1, &mut oracle);
        assert_eq!(reply.logits, expect, "quant server state diverged at step {t}");
    }
    router.close_session(int8_info.id).unwrap();
    router.close_session(f32_info.id).unwrap();
}

#[test]
fn idle_sessions_are_evicted_after_the_ttl() {
    let s = shape();
    let model = Arc::new(random_model(s, 42));
    let router = Router::builder()
        .shape(s)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(1))
        .session_ttl(Duration::from_millis(50))
        .engine(Box::new(CpuSingleEngine::new(model)))
        .build()
        .unwrap();

    let info = router.open_session(Precision::F32).unwrap();
    let frame: Vec<f32> = window(s, 6)[..s.input_dim].to_vec();
    router.classify_stream(info.id, frame.clone(), None).unwrap();

    std::thread::sleep(Duration::from_millis(250));

    // Whichever path noticed first — the scheduler's periodic sweep
    // (not_found after removal) or a lazy lookup (expired) — the
    // session is gone and the eviction was counted exactly once.
    let err = router.classify_stream(info.id, frame, None).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<ServeError>(),
            Some(ServeError::SessionExpired(_) | ServeError::SessionNotFound(_))
        ),
        "{err:#}"
    );
    assert_eq!(router.metrics.sessions_expired.load(Ordering::Relaxed), 1);
    assert_eq!(router.metrics.sessions_open.load(Ordering::Relaxed), 0);
    assert!(!router.sessions().contains(info.id));
}

// ---- session affinity under failover ---------------------------------

/// Stream-capable engine that starts failing after `fail_after` calls —
/// the fixture for forcing a mid-stream pool failure.
struct FlakyStreamEngine {
    shape: ModelShape,
    fail_after: usize,
    calls: AtomicUsize,
}

impl FlakyStreamEngine {
    fn new(shape: ModelShape, fail_after: usize) -> Self {
        Self { shape, fail_after, calls: AtomicUsize::new(0) }
    }
}

impl Engine for FlakyStreamEngine {
    fn target(&self) -> Target {
        Target::CpuSingle
    }

    fn supported_batches(&self) -> &[usize] {
        &[]
    }

    fn infer(&self, x: &Tensor) -> anyhow::Result<Tensor> {
        let b = x.shape()[0];
        Ok(Tensor::new(vec![b, self.shape.num_classes], vec![0.0; b * self.shape.num_classes]))
    }

    fn infer_stream(
        &self,
        _frames: &[f32],
        steps: usize,
        _state: &mut StreamState,
    ) -> anyhow::Result<Vec<f32>> {
        if self.calls.fetch_add(1, Ordering::Relaxed) >= self.fail_after {
            anyhow::bail!("flaky engine down");
        }
        // Class 0 flagged per step.
        let mut logits = vec![0.0; steps * self.shape.num_classes];
        for t in 0..steps {
            logits[t * self.shape.num_classes] = 1.0;
        }
        Ok(logits)
    }

    fn supports_streaming(&self) -> bool {
        true
    }
}

/// Healthy second pool; flags class 1 so replies are attributable.
struct SteadyStreamEngine {
    shape: ModelShape,
}

impl Engine for SteadyStreamEngine {
    fn target(&self) -> Target {
        Target::CpuMulti(2)
    }

    fn supported_batches(&self) -> &[usize] {
        &[]
    }

    fn infer(&self, x: &Tensor) -> anyhow::Result<Tensor> {
        let b = x.shape()[0];
        Ok(Tensor::new(vec![b, self.shape.num_classes], vec![0.0; b * self.shape.num_classes]))
    }

    fn infer_stream(
        &self,
        _frames: &[f32],
        steps: usize,
        _state: &mut StreamState,
    ) -> anyhow::Result<Vec<f32>> {
        let mut logits = vec![0.0; steps * self.shape.num_classes];
        for t in 0..steps {
            logits[t * self.shape.num_classes + 1] = 1.0;
        }
        Ok(logits)
    }

    fn supports_streaming(&self) -> bool {
        true
    }
}

#[test]
fn failover_migrates_the_session_pin_exactly_once() {
    let s = shape();
    let router = Router::builder()
        .shape(s)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(Duration::from_millis(1))
        .engine(Box::new(FlakyStreamEngine::new(s, 1)))
        .engine(Box::new(SteadyStreamEngine { shape: s }))
        .build()
        .unwrap();

    let info = router.open_session(Precision::F32).unwrap();
    assert_eq!(info.target, "cpu", "opens pin to the first stream-capable pool");
    let frame: Vec<f32> = vec![0.25; s.input_dim];

    // Step 1: the pinned pool is healthy.
    let r1 = router.classify_stream(info.id, frame.clone(), None).unwrap();
    assert_eq!(r1.target, "cpu");
    assert_eq!(r1.classes, vec![0]);
    assert_eq!(router.metrics.sessions_migrated.load(Ordering::Relaxed), 0);

    // Step 2: the pinned pool fails; the chunk fails over, the reply
    // names the pool that actually served it, and the pin migrates.
    let r2 = router.classify_stream(info.id, frame.clone(), None).unwrap();
    assert_eq!(r2.target, "cpu-multi", "failover must be visible in the reply");
    assert_eq!(r2.classes, vec![1]);
    assert_eq!(router.metrics.sessions_migrated.load(Ordering::Relaxed), 1);

    // Step 3: dispatched straight to the migrated pin — no second
    // migration, and the flaky pool is never retried.
    let r3 = router.classify_stream(info.id, frame, None).unwrap();
    assert_eq!(r3.target, "cpu-multi");
    assert_eq!(router.metrics.sessions_migrated.load(Ordering::Relaxed), 1);

    assert_eq!(router.close_session(info.id).unwrap(), 3);
}
