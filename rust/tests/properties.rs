//! Randomized property tests over coordinator + simulator invariants.
//!
//! The vendored crate set has no proptest, so generation is explicit:
//! `util::Rng` drives hundreds of random cases per property, and every
//! failure message includes the seed-derived case so it reproduces
//! deterministically.

use mobirnn::config::ModelShape;
use mobirnn::coordinator::plan_batch;
use mobirnn::simulator::{
    build_trace_with_slots, gpu_run, simulate_inference, DeviceProfile, Factorization, Target,
    TraceOpts,
};
use mobirnn::util::Rng;

fn random_shape(rng: &mut Rng) -> ModelShape {
    ModelShape {
        num_layers: 1 + rng.below(3) as usize,
        hidden: [8, 16, 32, 48, 64, 128, 256][rng.below(7) as usize],
        input_dim: 1 + rng.below(16) as usize,
        seq_len: 1 + rng.below(64) as usize,
        num_classes: 2 + rng.below(8) as usize,
    }
}

#[test]
fn prop_factorization_preserves_flops() {
    // Chopping work differently must never change the total arithmetic.
    let mut rng = Rng::new(101);
    for case in 0..300 {
        let shape = random_shape(&mut rng);
        let batch = 1 + rng.below(8) as usize;
        let slots = 1 + rng.below(31) as usize;
        let fine = build_trace_with_slots(shape, batch, Factorization::Fine, &TraceOpts::mobirnn(), slots);
        let coarse =
            build_trace_with_slots(shape, batch, Factorization::Coarse, &TraceOpts::mobirnn(), slots);
        assert_eq!(
            fine.total_flops(),
            coarse.total_flops(),
            "case {case}: {shape:?} batch {batch} slots {slots}"
        );
    }
}

#[test]
fn prop_coarse_never_slower_than_fine() {
    // The paper's core claim, as an invariant over the whole model space.
    let mut rng = Rng::new(102);
    let p = DeviceProfile::nexus5();
    for case in 0..120 {
        let shape = random_shape(&mut rng);
        let util = rng.next_f64() * 0.8;
        let fine = simulate_inference(&p, shape, 1, Target::Gpu(Factorization::Fine), util);
        let coarse = simulate_inference(&p, shape, 1, Target::Gpu(Factorization::Coarse), util);
        assert!(coarse <= fine, "case {case}: {shape:?} util {util}: coarse {coarse} fine {fine}");
    }
}

#[test]
fn prop_latency_monotone_in_load() {
    let mut rng = Rng::new(103);
    let p = DeviceProfile::nexus6p();
    for case in 0..40 {
        let shape = random_shape(&mut rng);
        for target in
            [Target::Gpu(Factorization::Coarse), Target::CpuSingle, Target::CpuMulti(4)]
        {
            let mut last = 0;
            for step in 0..10 {
                let util = step as f64 / 10.0;
                let t = simulate_inference(&p, shape, 1, target, util);
                assert!(
                    t >= last,
                    "case {case}: {shape:?} {target:?} util {util}: {t} < {last}"
                );
                last = t;
            }
        }
    }
}

#[test]
fn prop_latency_monotone_in_model_size() {
    // More layers or wider hidden can never be faster, on any target.
    let mut rng = Rng::new(104);
    let p = DeviceProfile::nexus5();
    for _ in 0..60 {
        let base = random_shape(&mut rng);
        let bigger_layers = ModelShape { num_layers: base.num_layers + 1, ..base };
        let bigger_hidden = ModelShape { hidden: base.hidden * 2, ..base };
        for target in
            [Target::Gpu(Factorization::Coarse), Target::CpuSingle, Target::CpuMulti(4)]
        {
            let t0 = simulate_inference(&p, base, 1, target, 0.0);
            assert!(simulate_inference(&p, bigger_layers, 1, target, 0.0) >= t0);
            assert!(simulate_inference(&p, bigger_hidden, 1, target, 0.0) >= t0);
        }
    }
}

#[test]
fn prop_gpu_accounting_identity() {
    // total == dispatch + alloc + compute + mem_stall + render_wait, always.
    let mut rng = Rng::new(105);
    let p = DeviceProfile::nexus5();
    for case in 0..150 {
        let shape = random_shape(&mut rng);
        let fact = if rng.below(2) == 0 { Factorization::Fine } else { Factorization::Coarse };
        let opts = TraceOpts {
            combined_gemm: rng.below(2) == 0,
            fused_pointwise: rng.below(2) == 0,
            mem_pool: rng.below(2) == 0,
            divergence_free: rng.below(2) == 0,
        };
        let util = rng.next_f64() * 0.9;
        let trace = build_trace_with_slots(shape, 1, fact, &opts, p.gpu_slots);
        let r = gpu_run(&p, &trace, util, 0);
        assert_eq!(
            r.total_ns,
            r.dispatch_ns + r.alloc_ns + r.compute_ns + r.mem_stall_ns + r.render_wait_ns,
            "case {case}: {shape:?} {fact:?} {opts:?} util {util}"
        );
        assert_eq!(r.num_launches as usize, trace.num_launches());
    }
}

#[test]
fn prop_every_optimization_helps_or_is_neutral() {
    // Toggling any single §3.2/3.3 optimization off must never make the
    // simulated system FASTER, for any shape.
    let mut rng = Rng::new(106);
    let p = DeviceProfile::nexus5();
    for _ in 0..60 {
        let shape = random_shape(&mut rng);
        let base_trace =
            build_trace_with_slots(shape, 1, Factorization::Coarse, &TraceOpts::mobirnn(), p.gpu_slots);
        let base = gpu_run(&p, &base_trace, 0.0, 0).total_ns;
        for i in 0..4 {
            let mut o = TraceOpts::mobirnn();
            match i {
                0 => o.combined_gemm = false,
                1 => o.fused_pointwise = false,
                2 => o.mem_pool = false,
                _ => o.divergence_free = false,
            }
            let t = build_trace_with_slots(shape, 1, Factorization::Coarse, &o, p.gpu_slots);
            let ablated = gpu_run(&p, &t, 0.0, 0).total_ns;
            assert!(ablated >= base, "{shape:?} toggle {i}: {ablated} < {base}");
        }
    }
}

#[test]
fn prop_batch_plans_conserve_and_terminate() {
    // Random compiled sets + random arrival counts: draining consumes
    // everything exactly once, padding bounded by the largest gap.
    let mut rng = Rng::new(107);
    for case in 0..500 {
        let mut sizes: Vec<usize> =
            (0..1 + rng.below(5)).map(|_| 1 + rng.below(64) as usize).collect();
        sizes.sort_unstable();
        sizes.dedup();
        let total = rng.below(256) as usize;
        let mut pending = total;
        let mut served = 0;
        let mut padding = 0;
        while pending > 0 {
            let p = plan_batch(pending, &sizes).expect("plan for nonzero pending");
            assert!(p.take >= 1 && p.take <= pending, "case {case}");
            pending -= p.take;
            served += p.take;
            padding += p.padding();
            // Padding only allowed on the final, short batch.
            if p.padding() > 0 {
                assert_eq!(pending, 0, "case {case}: padded mid-stream");
            }
        }
        assert_eq!(served, total);
        assert!(padding < *sizes.last().unwrap(), "case {case}");
    }
}

#[test]
fn prop_cpu_batch_linear() {
    // CPU latency scales exactly linearly in batch (no batching benefit —
    // which is WHY the GPU wins once batches form).
    let mut rng = Rng::new(108);
    let p = DeviceProfile::nexus5();
    for _ in 0..50 {
        let shape = random_shape(&mut rng);
        let b = 2 + rng.below(7) as usize;
        let t1 = simulate_inference(&p, shape, 1, Target::CpuSingle, 0.0) as f64;
        let tb = simulate_inference(&p, shape, b, Target::CpuSingle, 0.0) as f64;
        let ratio = tb / (t1 * b as f64);
        assert!((ratio - 1.0).abs() < 0.02, "{shape:?} b={b}: ratio {ratio}");
    }
}

#[test]
fn prop_gpu_batching_amortizes() {
    // GPU latency at batch B is strictly less than B sequential runs
    // (dispatch amortization — the coordinator's reason to batch).
    let mut rng = Rng::new(109);
    let p = DeviceProfile::nexus5();
    for _ in 0..50 {
        let shape = random_shape(&mut rng);
        let b = 2 + rng.below(7) as usize;
        let t1 = simulate_inference(&p, shape, 1, Target::Gpu(Factorization::Coarse), 0.0);
        let tb = simulate_inference(&p, shape, b, Target::Gpu(Factorization::Coarse), 0.0);
        assert!(tb < t1 * b as u64, "{shape:?} b={b}: {tb} !< {}", t1 * b as u64);
    }
}
