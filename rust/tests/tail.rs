//! Accuracy and parity gates of the dispatched fused LSTM gate tail
//! (DESIGN.md §14).
//!
//! Four layers of guarantee, from kernel to serving:
//!
//! 1. the FUSED tail output — not just the σ/tanh helpers — stays within
//!    its documented bounds of the libm oracle over a dense sweep of
//!    gate pre-activations in [-10, 10] (and is exactly the oracle under
//!    the forced-scalar ISA);
//! 2. the tail is monotone along each gate axis and hard-saturates at
//!    the Padé clamp edges, so approximation error can shrink margins
//!    but never invert an ordering along a gate;
//! 3. batched, `PlanPool`-partitioned (any thread count) and streaming
//!    execution stay bit-for-bit equal, both precisions — the §11/§13
//!    parity contracts survive the tail going through the dispatch
//!    table;
//! 4. end to end through a live router, argmax agrees with a libm-tail
//!    oracle forward on ≥ 99% of HAR windows (exactly 100% when the
//!    scalar ISA is active, where the engine IS the oracle).
//!
//! The fixture follows `tests/quant.rs`: contractive recurrence dynamics
//! (the regime trained classifiers inhabit) plus a class-spread honesty
//! guard so the parity bar cannot be met by a degenerate predictor.

use mobirnn::config::ModelShape;
use mobirnn::coordinator::{CpuSingleEngine, OffloadPolicy, Router};
use mobirnn::har;
use mobirnn::lstm::{
    lstm_tail, lstm_tail_scalar, BatchArena, LstmCellWeights, LstmModel, PlanPool, StreamState,
    FORGET_BIAS, TAIL_C_MAX_ABS_ERR, TAIL_H_MAX_ABS_ERR,
};
use mobirnn::simulator::Target;
use mobirnn::tensor::{argmax_slice, gemv_into, Tensor};
use mobirnn::util::Rng;

use std::sync::Arc;

fn scalar_active() -> bool {
    mobirnn::kernel::active() == mobirnn::kernel::KernelIsa::Scalar
}

#[test]
fn tail_error_bound_vs_libm_on_dense_sweep() {
    // One giant row (odd hid — the vector kernels' remainder path runs
    // too): gate k gets pre-activations i=x, g=x, f=x-1 (so f+bias
    // sweeps [-10,10] as well), o=x, with x dense over [-10, 10].
    let hid = 20_001usize;
    let xs: Vec<f32> = (0..hid).map(|k| -10.0 + k as f32 * 1e-3).collect();
    let mut gates = vec![0.0f32; 4 * hid];
    for k in 0..hid {
        gates[k] = xs[k];
        gates[hid + k] = xs[k];
        gates[2 * hid + k] = xs[k] - FORGET_BIAS;
        gates[3 * hid + k] = xs[k];
    }
    for c0 in [-2.0f32, -0.7, 0.0, 1.3, 2.0] {
        let (mut h, mut c) = (vec![0.0f32; hid], vec![c0; hid]);
        let (mut h_ref, mut c_ref) = (vec![0.0f32; hid], vec![c0; hid]);
        lstm_tail(&gates, &mut h, &mut c, 1, hid);
        lstm_tail_scalar(&gates, &mut h_ref, &mut c_ref, 1, hid);
        for k in 0..hid {
            let dc = (c[k] - c_ref[k]).abs();
            let dh = (h[k] - h_ref[k]).abs();
            assert!(
                dc <= TAIL_C_MAX_ABS_ERR,
                "x={} c0={c0}: |Δc| {dc} > {TAIL_C_MAX_ABS_ERR}",
                xs[k]
            );
            assert!(
                dh <= TAIL_H_MAX_ABS_ERR,
                "x={} c0={c0}: |Δh| {dh} > {TAIL_H_MAX_ABS_ERR}",
                xs[k]
            );
            if scalar_active() {
                assert_eq!(c[k].to_bits(), c_ref[k].to_bits(), "scalar ISA must BE the oracle");
                assert_eq!(h[k].to_bits(), h_ref[k].to_bits(), "scalar ISA must BE the oracle");
            }
        }
    }
}

/// One dispatched tail update at hid = 1.
fn tail1(i: f32, g: f32, f: f32, o: f32, c0: f32) -> (f32, f32) {
    let gates = [i, g, f, o];
    let (mut h, mut c) = ([0.0f32], [c0]);
    lstm_tail(&gates, &mut h, &mut c, 1, 1);
    (c[0], h[0])
}

#[test]
fn tail_monotone_and_saturating_at_clamp_edges() {
    // Each probe pins the other gates where BOTH implementations are
    // exact (tanh(0) = 0 and σ(0) = 0.5 hold bit-for-bit in libm and in
    // the Padé rational), so the swept axis is isolated.
    let sweep: Vec<f32> = (0..=400).map(|k| -10.0 + k as f32 * 0.05).collect();

    // (a) forget-gate axis: g = 0 kills the input term exactly, so
    // c' = σ(f + bias) · 0.8 must be nondecreasing in f.
    let mut prev = f32::NEG_INFINITY;
    for &f in &sweep {
        let (c1, _) = tail1(0.0, 0.0, f, 0.0, 0.8);
        assert!(c1 >= prev - 1e-6, "c' dipped at f={f}: {c1} < {prev}");
        prev = c1;
    }
    // Hard saturation beyond the σ clamp (|f + bias| ≥ 7): the Padé tail
    // is exactly constant there; both tails preserve ~all of the cell.
    if !scalar_active() {
        let (c_edge, _) = tail1(0.0, 0.0, 6.01, 0.0, 0.8);
        for f in [7.0f32, 50.0, 1e9] {
            let (c1, _) = tail1(0.0, 0.0, f, 0.0, 0.8);
            assert_eq!(c1.to_bits(), c_edge.to_bits(), "not constant beyond clamp at f={f}");
        }
    }
    let (c_sat, _) = tail1(0.0, 0.0, 1e9, 0.0, 0.8);
    assert!((c_sat - 0.8).abs() < 1e-3, "saturated forget leaked cell: {c_sat}");

    // (b) candidate-gate axis: i = 0 makes the input term 0.5 · tanh(g)
    // exactly; c0 = 0 kills the forget term. Monotone in g, saturating
    // beyond the tanh clamp (|g| ≥ 3.5).
    let mut prev = f32::NEG_INFINITY;
    for &g in &sweep {
        let (c1, _) = tail1(0.0, g, 0.0, 0.0, 0.0);
        assert!(c1 >= prev - 1e-6, "c' dipped at g={g}: {c1} < {prev}");
        prev = c1;
    }
    if !scalar_active() {
        let (c_edge, _) = tail1(0.0, 3.5, 0.0, 0.0, 0.0);
        for g in [4.0f32, 100.0, 1e9] {
            let (c1, _) = tail1(0.0, g, 0.0, 0.0, 0.0);
            assert_eq!(c1.to_bits(), c_edge.to_bits(), "not constant beyond clamp at g={g}");
        }
    }
    let (c_sat, _) = tail1(0.0, 1e9, 0.0, 0.0, 0.0);
    assert!((c_sat - 0.5).abs() < 1e-3, "saturated candidate off target: {c_sat}");

    // (c) output-gate axis: i = g = 0 and f = 0 fix c' = σ(bias) · 0.8,
    // so h' = σ(o) · tanh(c') must be nondecreasing in o.
    let mut prev = f32::NEG_INFINITY;
    for &o in &sweep {
        let (_, h1) = tail1(0.0, 0.0, 0.0, o, 0.8);
        assert!(h1 >= prev - 1e-6, "h' dipped at o={o}: {h1} < {prev}");
        prev = h1;
    }
}

/// The contractive parity fixture, returning the raw weight parts so the
/// oracle test below can run its own libm-tail forward over them.
fn decisive_parts(shape: ModelShape, seed: u64) -> (Vec<LstmCellWeights>, Tensor, Tensor) {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut in_dim = shape.input_dim;
    for _ in 0..shape.num_layers {
        let wn = (in_dim + shape.hidden) * 4 * shape.hidden;
        let w: Vec<f32> = (0..wn).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let b: Vec<f32> = (0..4 * shape.hidden).map(|_| rng.uniform(-0.2, 0.2)).collect();
        layers.push(LstmCellWeights::new(
            Tensor::new(vec![in_dim + shape.hidden, 4 * shape.hidden], w),
            Tensor::new(vec![4 * shape.hidden], b),
            in_dim,
            shape.hidden,
        ));
        in_dim = shape.hidden;
    }
    let w_out: Vec<f32> =
        (0..shape.hidden * shape.num_classes).map(|_| rng.uniform(-0.5, 0.5)).collect();
    (
        layers,
        Tensor::new(vec![shape.hidden, shape.num_classes], w_out),
        Tensor::new(vec![shape.num_classes], vec![0.0; shape.num_classes]),
    )
}

fn decisive_model(shape: ModelShape, seed: u64) -> LstmModel {
    let (layers, w_out, b_out) = decisive_parts(shape, seed);
    LstmModel::new(shape, layers, w_out, b_out)
}

#[test]
fn tail_preserves_batched_streaming_pooled_parity() {
    // The §11/§13 bit-parity contracts, re-asserted with the tail going
    // through the dispatch table: inline batched, pool-partitioned at
    // every thread count, and streamed-one-window must all agree
    // bit-for-bit, f32 AND int8.
    let shape = ModelShape::default();
    let model = decisive_model(shape, 42);
    let qmodel = model.quantize();
    let ds = har::generate(7, 51);

    let mut inline = BatchArena::new(shape);
    let batched = model.forward_batch(&ds.x, &mut inline);
    let batched_q = qmodel.forward_batch_quant(&ds.x, &mut inline);

    for threads in [1usize, 2, 3, 5, 8] {
        let mut pooled = BatchArena::with_pool(shape, Arc::new(PlanPool::new(threads)));
        let p = model.forward_batch(&ds.x, &mut pooled);
        assert_eq!(batched.data(), p.data(), "f32 pooled parity broke at {threads} threads");
        let pq = qmodel.forward_batch_quant(&ds.x, &mut pooled);
        assert_eq!(batched_q.data(), pq.data(), "int8 pooled parity broke at {threads} threads");
    }

    let (t, c) = (shape.seq_len, shape.num_classes);
    for i in 0..ds.len() {
        let mut st = StreamState::new(shape);
        let logits = model.stream_chunk(ds.window(i), t, &mut st);
        assert_eq!(batched.row(i), &logits[(t - 1) * c..], "f32 stream parity, window {i}");
        let mut st = StreamState::new(shape);
        let logits_q = qmodel.stream_chunk_quant(ds.window(i), t, &mut st);
        assert_eq!(batched_q.row(i), &logits_q[(t - 1) * c..], "int8 stream parity, window {i}");
    }
}

/// Libm-tail oracle forward: the engine's exact GEMMs (dispatched — the
/// GEMM half is common-moded out) with `lstm_tail_scalar` as the tail
/// and the head accumulated in `head_into`'s exact order. The ONLY
/// difference vs the live engine is the tail kernel.
fn oracle_predict(
    layers: &[LstmCellWeights],
    w_out: &Tensor,
    b_out: &Tensor,
    shape: ModelShape,
    window: &[f32],
) -> usize {
    let hid = shape.hidden;
    let mut h = vec![vec![0.0f32; hid]; shape.num_layers];
    let mut c = vec![vec![0.0f32; hid]; shape.num_layers];
    let mut gates = vec![0.0f32; 4 * hid];
    for t in 0..shape.seq_len {
        let x = &window[t * shape.input_dim..(t + 1) * shape.input_dim];
        for li in 0..shape.num_layers {
            let lw = &layers[li];
            gates.copy_from_slice(lw.b.data());
            let input: Vec<f32> = if li == 0 { x.to_vec() } else { h[li - 1].clone() };
            gemv_into(&mut gates, lw.w.data(), &input);
            gemv_into(&mut gates, &lw.w.data()[lw.input_dim * 4 * hid..], &h[li]);
            let (hs, cs) = (&mut h[li], &mut c[li]);
            lstm_tail_scalar(&gates, hs, cs, 1, hid);
        }
    }
    let mut logits = b_out.data().to_vec();
    for (r, &hv) in h[shape.num_layers - 1].iter().enumerate() {
        for (l, wv) in logits.iter_mut().zip(w_out.row(r)) {
            *l += hv * wv;
        }
    }
    argmax_slice(&logits)
}

#[test]
fn argmax_parity_vs_libm_oracle_through_router() {
    // The serving-level gate: a live router (real engine, dispatched
    // tail) must agree with the libm-tail oracle on ≥ 99% of windows —
    // and exactly 100% under the forced-scalar ISA, where the dispatched
    // tail IS libm.
    let shape = ModelShape::default();
    let (layers, w_out, b_out) = decisive_parts(shape, 26);
    let model = Arc::new(LstmModel::new(shape, layers.clone(), w_out.clone(), b_out.clone()));
    let router = Router::builder()
        .shape(shape)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(std::time::Duration::from_millis(1))
        .engine(Box::new(CpuSingleEngine::new(model)))
        .build()
        .unwrap();
    let ds = har::generate(300, 17);
    let mut agree = 0usize;
    let mut oracle_class_seen = [false; har::NUM_CLASSES];
    for i in 0..ds.len() {
        let oracle = oracle_predict(&layers, &w_out, &b_out, shape, ds.window(i));
        oracle_class_seen[oracle] = true;
        let live = router.classify(ds.window(i).to_vec()).unwrap();
        assert_eq!(live.target, "cpu");
        if live.class == oracle {
            agree += 1;
        }
    }
    let rate = agree as f64 / ds.len() as f64;
    assert!(rate >= 0.99, "oracle agreement {rate:.4} < 0.99 ({agree}/{})", ds.len());
    if scalar_active() {
        assert_eq!(agree, ds.len(), "scalar ISA runs the oracle tail: agreement must be exact");
    }
    assert!(
        oracle_class_seen.iter().filter(|&&s| s).count() >= 2,
        "fixture degenerate: oracle predictions collapse to one class"
    );
}
