//! Cross-language golden test — the keystone correctness check.
//!
//! `python/compile/aot.py` ran 8 held-out HAR windows through the
//! TRAINED model using the Pallas-kernel graph and froze inputs+logits
//! into `artifacts/golden_L2_H32.bin`. Here the SAME windows go through
//! (a) the PJRT-compiled artifact and (b) the native Rust engine, both
//! loaded from the same MRNW weights. If either path drifts from the JAX
//! oracle, serving is broken no matter what the latency numbers say.

use mobirnn::config::Manifest;
use mobirnn::lstm::model::InferenceState;
use mobirnn::lstm::{BatchArena, LstmModel, WeightFile};
use mobirnn::runtime::Runtime;
use mobirnn::tensor::Tensor;

/// MRNG v1: magic | u32 ver,B,T,D,C | f32 x[B*T*D] | f32 logits[B*C].
fn read_golden(path: &std::path::Path) -> (Tensor, Tensor) {
    let raw = std::fs::read(path).expect("golden file");
    assert_eq!(&raw[..4], b"MRNG");
    let word = |i: usize| {
        u32::from_le_bytes(raw[4 + 4 * i..8 + 4 * i].try_into().unwrap()) as usize
    };
    let (ver, b, t, d, c) = (word(0), word(1), word(2), word(3), word(4));
    assert_eq!(ver, 1);
    let f32s: Vec<f32> = raw[24..]
        .chunks_exact(4)
        .map(|ch| f32::from_le_bytes(ch.try_into().unwrap()))
        .collect();
    assert_eq!(f32s.len(), b * t * d + b * c);
    let x = Tensor::new(vec![b, t, d], f32s[..b * t * d].to_vec());
    let logits = Tensor::new(vec![b, c], f32s[b * t * d..].to_vec());
    (x, logits)
}

fn manifest() -> Option<Manifest> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(Manifest::load(dir).unwrap())
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn pjrt_matches_jax_golden() {
    let Some(man) = manifest() else { return };
    let (x, expected) = read_golden(&man.path(&man.golden.file));
    let rt = Runtime::start(&man).unwrap();
    let got = rt.execute(&man.golden.variant, x).unwrap();
    assert_eq!(got.shape(), expected.shape());
    let diff = got.max_abs_diff(&expected);
    // Same HLO graph, same weights, same XLA backend as the python dump:
    // agreement should be at float-noise level.
    assert!(diff < 1e-4, "PJRT drifted from JAX golden: max|Δ| = {diff}");
}

#[test]
fn native_engine_matches_jax_golden() {
    let Some(man) = manifest() else { return };
    let (x, expected) = read_golden(&man.path(&man.golden.file));
    let info = man.variant(&man.golden.variant).unwrap();
    let wf = WeightFile::load(man.path(&info.weights)).unwrap();
    let model = LstmModel::from_weight_file(info.shape(), &wf).unwrap();
    let mut arena = BatchArena::new(model.shape);
    let got = model.forward_batch(&x, &mut arena);
    let diff = got.max_abs_diff(&expected);
    // Different accumulation order than XLA: allow a slightly wider but
    // still tight envelope over 128 recurrent steps.
    assert!(diff < 2e-3, "native engine drifted from JAX golden: max|Δ| = {diff}");
    // Predictions must agree exactly.
    assert_eq!(got.argmax_rows(), expected.argmax_rows());
    // The per-window oracle must agree with the batched plan bit-for-bit
    // on the trained weights too, not just on random ones.
    let mut st = InferenceState::new(model.shape);
    for i in 0..x.shape()[0] {
        let single = model.forward_window(x.slab(i), &mut st);
        assert_eq!(got.row(i), &single[..], "batched plan drifted from oracle at row {i}");
    }
}

#[test]
fn golden_predictions_match_manifest() {
    let Some(man) = manifest() else { return };
    let (_, logits) = read_golden(&man.path(&man.golden.file));
    let preds: Vec<u32> = logits.argmax_rows().iter().map(|&v| v as u32).collect();
    assert_eq!(preds, man.golden.predictions, "manifest predictions stale");
    assert_eq!(man.golden.labels.len(), preds.len());
}

#[test]
fn batch_variants_agree_with_each_other() {
    // The SAME window through B=1 and B=8 artifacts must give the same
    // logits — batching must never change answers.
    let Some(man) = manifest() else { return };
    let (x, _) = read_golden(&man.path(&man.golden.file));
    let rt = Runtime::start(&man).unwrap();
    let shape = man.variant(&man.golden.variant).unwrap().shape();
    let window = x.slab(0).to_vec();

    let out8 = rt.execute(&shape.variant_name(8), x.clone()).unwrap();
    let x1 = Tensor::new(vec![1, shape.seq_len, shape.input_dim], window);
    let out1 = rt.execute(&shape.variant_name(1), x1).unwrap();
    for (a, b) in out1.row(0).iter().zip(out8.row(0)) {
        assert!((a - b).abs() < 1e-4, "batching changed logits: {a} vs {b}");
    }
}

#[test]
fn trained_model_beats_chance_on_fresh_data() {
    // End-to-end accuracy signal through the PJRT path on data the
    // trainer never saw (different seed stream than train/test).
    let Some(man) = manifest() else { return };
    let rt = Runtime::start(&man).unwrap();
    let shape = mobirnn::config::ModelShape::default();
    let ds = mobirnn::har::generate(64, 987654);
    let mut correct = 0;
    for i in 0..ds.len() {
        let x = Tensor::new(
            vec![1, shape.seq_len, shape.input_dim],
            ds.window(i).to_vec(),
        );
        let logits = rt.execute(&shape.variant_name(1), x).unwrap();
        if logits.argmax_rows()[0] == ds.labels[i] as usize {
            correct += 1;
        }
    }
    let acc = correct as f64 / ds.len() as f64;
    assert!(acc > 0.5, "PJRT accuracy on fresh synthetic HAR too low: {acc}");
}
