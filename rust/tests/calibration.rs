//! Calibration suite: every numeric anchor quoted from the paper must
//! hold on the simulated devices (DESIGN.md §6). If someone retunes a
//! device constant and silently breaks a figure, this fails first.

use mobirnn::config::ModelShape;
use mobirnn::simulator::{
    simulate_gpu_with_opts, simulate_inference, DeviceProfile, Factorization, Target, TraceOpts,
};

fn ms(ns: u64) -> f64 {
    ns as f64 / 1e6
}

#[test]
fn anchor_cpu_142ms() {
    // §4.4: "single thread CPU time is 142ms on average" (Nexus 5, 2l/32h).
    let t = simulate_inference(&DeviceProfile::nexus5(), ModelShape::default(), 1, Target::CpuSingle, 0.0);
    assert!((ms(t) - 142.0).abs() < 10.0, "got {} ms", ms(t));
}

#[test]
fn anchor_nexus5_speedup_393() {
    // §4.2: "at least 3.93 times faster on the GPU compared to the CPU".
    let p = DeviceProfile::nexus5();
    let s = ModelShape::default();
    let cpu = simulate_inference(&p, s, 1, Target::CpuSingle, 0.0) as f64;
    let gpu = simulate_inference(&p, s, 1, Target::Gpu(Factorization::Coarse), 0.0) as f64;
    let speedup = cpu / gpu;
    assert!((speedup - 3.93).abs() < 0.3, "got {speedup}");
}

#[test]
fn anchor_nexus6p_speedup_283() {
    // §4.2: 2.83x on the Nexus 6P.
    let p = DeviceProfile::nexus6p();
    let s = ModelShape::default();
    let cpu = simulate_inference(&p, s, 1, Target::CpuSingle, 0.0) as f64;
    let gpu = simulate_inference(&p, s, 1, Target::Gpu(Factorization::Coarse), 0.0) as f64;
    let speedup = cpu / gpu;
    assert!((speedup - 2.83).abs() < 0.35, "got {speedup}");
}

#[test]
fn anchor_cuda_style_4x_slower() {
    // §3.1/abstract: desktop-style offloading "up to 4 times slower".
    let p = DeviceProfile::nexus5();
    let worst = [(1usize, 32usize), (2, 32), (3, 32), (2, 64), (2, 128), (2, 256)]
        .iter()
        .map(|&(l, h)| {
            let s = ModelShape::new(l, h);
            let cpu = simulate_inference(&p, s, 1, Target::CpuSingle, 0.0) as f64;
            let gpu = simulate_inference(&p, s, 1, Target::Gpu(Factorization::Fine), 0.0) as f64;
            gpu / cpu
        })
        .fold(0.0f64, f64::max);
    assert!((3.2..4.8).contains(&worst), "worst fine slowdown {worst}");
}

#[test]
fn anchor_6p_cpu_faster_gpu_comparable() {
    // §4.2: "running the RNN model on the CPU is faster on the Nexus 6P
    // ... the performance of the RNN model on the GPU are comparable".
    let s = ModelShape::default();
    let n5 = DeviceProfile::nexus5();
    let n6 = DeviceProfile::nexus6p();
    let cpu5 = simulate_inference(&n5, s, 1, Target::CpuSingle, 0.0) as f64;
    let cpu6 = simulate_inference(&n6, s, 1, Target::CpuSingle, 0.0) as f64;
    assert!(cpu6 < 0.8 * cpu5);
    let gpu5 = simulate_inference(&n5, s, 1, Target::Gpu(Factorization::Coarse), 0.0) as f64;
    let gpu6 = simulate_inference(&n6, s, 1, Target::Gpu(Factorization::Coarse), 0.0) as f64;
    assert!((gpu6 / gpu5 - 1.0).abs() < 0.2, "GPU ratio {}", gpu6 / gpu5);
}

#[test]
fn anchor_mt_cpu_captures_70_percent() {
    // §4/abstract: multithreaded CPU gets ≥70.5% of the GPU benefit.
    let p = DeviceProfile::nexus5();
    for (l, h) in [(1, 32), (2, 32), (3, 32), (2, 64), (2, 128), (2, 256)] {
        let s = ModelShape::new(l, h);
        let single = simulate_inference(&p, s, 1, Target::CpuSingle, 0.0) as f64;
        let multi = simulate_inference(&p, s, 1, Target::CpuMulti(4), 0.0) as f64;
        let gpu = simulate_inference(&p, s, 1, Target::Gpu(Factorization::Coarse), 0.0) as f64;
        let frac = (single - multi) / (single - gpu);
        assert!(frac >= 0.705, "{l}l/{h}h: {frac}");
    }
}

#[test]
fn anchor_gpu_32_percent_over_mt() {
    // §4.4: "the GPU gives an average of 32% speed up over the
    // multithreaded version across the models".
    let p = DeviceProfile::nexus5();
    let gains: Vec<f64> = [(1, 32), (2, 32), (3, 32), (2, 64), (2, 128), (2, 256)]
        .iter()
        .map(|&(l, h)| {
            let s = ModelShape::new(l, h);
            let multi = simulate_inference(&p, s, 1, Target::CpuMulti(4), 0.0) as f64;
            let gpu = simulate_inference(&p, s, 1, Target::Gpu(Factorization::Coarse), 0.0) as f64;
            multi / gpu - 1.0
        })
        .collect();
    let mean = gains.iter().sum::<f64>() / gains.len() as f64;
    assert!((0.15..0.55).contains(&mean), "mean GPU gain over MT = {mean}");
}

#[test]
fn anchor_fig7_crossover() {
    // §4.5: low/medium load → offload wins; high load → CPU wins.
    let p = DeviceProfile::nexus6p();
    let s = ModelShape::default();
    for (util, gpu_should_win) in [(0.15, true), (0.40, true), (0.78, false)] {
        let cpu = simulate_inference(&p, s, 1, Target::CpuSingle, util) as f64;
        let gpu = simulate_inference(&p, s, 1, Target::Gpu(Factorization::Coarse), util) as f64;
        assert_eq!(gpu < cpu, gpu_should_win, "util {util}: gpu {gpu} cpu {cpu}");
    }
}

// ---- ablation directions (§3.2/3.3): every optimization must help ----

#[test]
fn ablation_memory_pool_helps() {
    let p = DeviceProfile::nexus5();
    let s = ModelShape::default();
    let pooled = simulate_gpu_with_opts(&p, s, 1, Factorization::Coarse, &TraceOpts::mobirnn(), 0.0);
    let mut o = TraceOpts::mobirnn();
    o.mem_pool = false;
    let unpooled = simulate_gpu_with_opts(&p, s, 1, Factorization::Coarse, &o, 0.0);
    assert!(
        unpooled as f64 > 1.3 * pooled as f64,
        "on-demand allocation should hurt clearly: {pooled} vs {unpooled}"
    );
}

#[test]
fn ablation_fused_pointwise_helps() {
    let p = DeviceProfile::nexus5();
    let s = ModelShape::default();
    let fused = simulate_gpu_with_opts(&p, s, 1, Factorization::Coarse, &TraceOpts::mobirnn(), 0.0);
    let mut o = TraceOpts::mobirnn();
    o.fused_pointwise = false;
    let unfused = simulate_gpu_with_opts(&p, s, 1, Factorization::Coarse, &o, 0.0);
    assert!(unfused > fused, "{unfused} !> {fused}");
}

#[test]
fn ablation_combined_gemm_helps() {
    let p = DeviceProfile::nexus5();
    let s = ModelShape::default();
    let combined = simulate_gpu_with_opts(&p, s, 1, Factorization::Coarse, &TraceOpts::mobirnn(), 0.0);
    let mut o = TraceOpts::mobirnn();
    o.combined_gemm = false;
    let split = simulate_gpu_with_opts(&p, s, 1, Factorization::Coarse, &o, 0.0);
    assert!(split > combined, "{split} !> {combined}");
}

#[test]
fn ablation_divergence_free_helps() {
    let p = DeviceProfile::nexus5();
    let s = ModelShape::default();
    let clean = simulate_gpu_with_opts(&p, s, 1, Factorization::Coarse, &TraceOpts::mobirnn(), 0.0);
    let mut o = TraceOpts::mobirnn();
    o.divergence_free = false;
    let divergent = simulate_gpu_with_opts(&p, s, 1, Factorization::Coarse, &o, 0.0);
    assert!(divergent > clean, "{divergent} !> {clean}");
}

#[test]
fn ablation_all_off_is_much_worse() {
    // The naive port (no §3.2/3.3 optimizations, still coarse) should be
    // several times slower than MobiRNN.
    let p = DeviceProfile::nexus5();
    let s = ModelShape::default();
    let mobirnn = simulate_gpu_with_opts(&p, s, 1, Factorization::Coarse, &TraceOpts::mobirnn(), 0.0);
    let naive = simulate_gpu_with_opts(&p, s, 1, Factorization::Coarse, &TraceOpts::naive(), 0.0);
    assert!(naive as f64 > 2.0 * mobirnn as f64, "naive {naive} vs mobirnn {mobirnn}");
}
