//! Accuracy gates of the int8 quantized path (DESIGN.md §10).
//!
//! Three layers of guarantee, from kernel to serving:
//!
//! 1. the fast sigmoid/tanh approximations respect their DOCUMENTED
//!    max-abs-error bounds over a dense sweep of [-10, 10], are
//!    monotone non-decreasing, and saturate at the extremes;
//! 2. weight pack → unpack round-trips within half a quantization step
//!    per output channel (the information-theoretic floor of symmetric
//!    int8);
//! 3. end to end, on seeded HAR-shaped windows, the int8 `predict`
//!    agrees with the f32 oracle's argmax on ≥ 99% of windows — through
//!    the model API and through a real router with the quant engine
//!    registered.
//!
//! The parity fixture is chosen for CONTRACTIVE recurrence dynamics
//! (weights ~1.5× the shared random fixture's scale, still moderate):
//! in the contractive regime per-step quantization error DECAYS through
//! the recurrence instead of compounding, which is the regime trained
//! LSTM classifiers operate in. The failure mode this avoids is real
//! and worth naming: at ~3× larger weights a random LSTM becomes a
//! chaotic map — a one-half-step perturbation flips a near-threshold
//! gate, trajectories bifurcate, and argmax agreement collapses toward
//! chance for ANY perturbation (a different compiler's float
//! contraction included), measuring nothing about quantization
//! quality. The margin guard below keeps the fixture honest
//! (predictions must spread across classes).

use mobirnn::config::ModelShape;
use mobirnn::coordinator::{ClassifyOptions, OffloadPolicy, Precision, Router};
use mobirnn::har;
use mobirnn::lstm::model::InferenceState;
use mobirnn::lstm::quant::PackedQuantMatrix;
use mobirnn::lstm::{
    fast_sigmoid, fast_tanh, BatchArena, LstmCellWeights, LstmModel, SIGMOID_MAX_ABS_ERR,
    TANH_MAX_ABS_ERR,
};
use mobirnn::simulator::Target;
use mobirnn::tensor::Tensor;
use mobirnn::util::Rng;

/// Numerically-stable logistic oracle (the f32 path's exact form).
fn sigmoid_oracle(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Dense sweep of [-10, 10]: 200k points, step 1e-4.
fn sweep() -> impl Iterator<Item = f32> {
    (0..=200_000).map(|i| -10.0 + i as f32 * 1e-4)
}

#[test]
fn fast_tanh_error_bound_on_dense_sweep() {
    let mut worst = 0.0f32;
    for x in sweep() {
        let err = (fast_tanh(x) - x.tanh()).abs();
        worst = worst.max(err);
        assert!(err < TANH_MAX_ABS_ERR, "x={x}: err {err} >= {TANH_MAX_ABS_ERR}");
    }
    // The bound must be tight-ish, not vacuous: the observed max sits
    // within an order of magnitude of the documented bound.
    assert!(worst > TANH_MAX_ABS_ERR / 10.0, "bound is vacuous: worst {worst}");
}

#[test]
fn fast_sigmoid_error_bound_on_dense_sweep() {
    let mut worst = 0.0f32;
    for x in sweep() {
        let err = (fast_sigmoid(x) - sigmoid_oracle(x)).abs();
        worst = worst.max(err);
        assert!(err < SIGMOID_MAX_ABS_ERR, "x={x}: err {err} >= {SIGMOID_MAX_ABS_ERR}");
    }
    assert!(worst > SIGMOID_MAX_ABS_ERR / 10.0, "bound is vacuous: worst {worst}");
}

#[test]
fn fast_tail_monotone_nondecreasing() {
    // Monotone within one f32 rounding step (1e-6 slack): a genuine dip
    // would be orders of magnitude larger than one ulp near 1.0.
    let mut prev_t = f32::NEG_INFINITY;
    let mut prev_s = f32::NEG_INFINITY;
    for x in sweep() {
        let t = fast_tanh(x);
        let s = fast_sigmoid(x);
        assert!(t >= prev_t - 1e-6, "tanh dip at x={x}: {t} < {prev_t}");
        assert!(s >= prev_s - 1e-6, "sigmoid dip at x={x}: {s} < {prev_s}");
        prev_t = t;
        prev_s = s;
    }
}

#[test]
fn fast_tail_saturates_at_extremes() {
    // Odd/even structure and hard saturation beyond the clamp.
    assert_eq!(fast_tanh(0.0), 0.0);
    assert_eq!(fast_sigmoid(0.0), 0.5);
    for x in [4.0f32, 10.0, 100.0, 1e9] {
        assert_eq!(fast_tanh(x), fast_tanh(4.0), "constant beyond the clamp");
        assert!(fast_tanh(x) > 0.999 && fast_tanh(x) <= 1.0);
        assert!(fast_tanh(-x) < -0.999 && fast_tanh(-x) >= -1.0);
        assert_eq!(fast_tanh(-x), -fast_tanh(x), "odd symmetry is exact in f32");
    }
    for x in [10.0f32, 100.0, 1e9] {
        assert!(fast_sigmoid(x) > 0.999 && fast_sigmoid(x) <= 1.0);
        assert!(fast_sigmoid(-x) < 1e-3 && fast_sigmoid(-x) >= 0.0);
    }
}

#[test]
fn pack_round_trip_error_within_per_channel_half_step() {
    // Per the satellite spec: pack → unpack error per channel within the
    // per-channel scale's half-step, on a realistically-shaped layer
    // matrix ([I+H, 4H] halves at the paper-default geometry).
    let mut rng = Rng::new(91);
    for (k, n) in [(9usize, 128usize), (32, 128), (41, 24)] {
        let w: Vec<f32> = (0..k * n).map(|_| rng.uniform(-0.7, 0.7)).collect();
        let p = PackedQuantMatrix::pack(&w, k, n);
        let back = p.unpack();
        for j in 0..n {
            let half_step = 0.5 * p.scales[j];
            for r in 0..k {
                let err = (w[r * n + j] - back[r * n + j]).abs();
                assert!(
                    err <= half_step + 1e-7,
                    "channel {j} row {r}: err {err} > half-step {half_step}"
                );
            }
        }
    }
}

/// The parity fixture: a decisive stacked LSTM (see module docs) plus
/// seeded HAR-shaped windows.
fn decisive_model(shape: ModelShape, seed: u64) -> LstmModel {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut in_dim = shape.input_dim;
    for _ in 0..shape.num_layers {
        let wn = (in_dim + shape.hidden) * 4 * shape.hidden;
        let w: Vec<f32> = (0..wn).map(|_| rng.uniform(-0.3, 0.3)).collect();
        let b: Vec<f32> = (0..4 * shape.hidden).map(|_| rng.uniform(-0.2, 0.2)).collect();
        layers.push(LstmCellWeights::new(
            Tensor::new(vec![in_dim + shape.hidden, 4 * shape.hidden], w),
            Tensor::new(vec![4 * shape.hidden], b),
            in_dim,
            shape.hidden,
        ));
        in_dim = shape.hidden;
    }
    let w_out: Vec<f32> =
        (0..shape.hidden * shape.num_classes).map(|_| rng.uniform(-0.5, 0.5)).collect();
    LstmModel::new(
        shape,
        layers,
        Tensor::new(vec![shape.hidden, shape.num_classes], w_out),
        Tensor::new(vec![shape.num_classes], vec![0.0; shape.num_classes]),
    )
}

#[test]
fn end_to_end_argmax_parity_at_least_99_percent() {
    let shape = ModelShape::default();
    let model = decisive_model(shape, 26);
    let qmodel = model.quantize();
    let ds = har::generate(300, 17);
    let mut st = InferenceState::new(shape);
    let mut arena = BatchArena::new(shape);

    let mut agree = 0usize;
    let mut f32_class_seen = [false; har::NUM_CLASSES];
    for i in 0..ds.len() {
        let w = ds.window(i);
        let f = model.predict(w, &mut st);
        let q = qmodel.predict(w, &mut arena);
        f32_class_seen[f] = true;
        if f == q {
            agree += 1;
        }
    }
    let rate = agree as f64 / ds.len() as f64;
    assert!(rate >= 0.99, "argmax agreement {rate:.4} < 0.99 ({agree}/{})", ds.len());
    // Fixture honesty guard: a degenerate one-class predictor would make
    // the parity bar vacuous.
    assert!(
        f32_class_seen.iter().filter(|&&s| s).count() >= 2,
        "fixture degenerate: f32 predictions collapse to one class"
    );
}

#[test]
fn batched_quant_parity_matches_single_row_quant() {
    // The quantized plan must be batch-size invariant the same way the
    // f32 plan is: B windows through forward_batch_quant give the same
    // logits as B single-row passes (scales are per row, so batching
    // cannot change the math).
    let shape = ModelShape::default();
    let model = decisive_model(shape, 7);
    let qmodel = model.quantize();
    let ds = har::generate(5, 23);
    let mut arena = BatchArena::new(shape);
    let batch = qmodel.forward_batch_quant(&ds.x, &mut arena);
    for i in 0..ds.len() {
        let single = qmodel.forward_rows_quant(ds.window(i), 1, &mut arena);
        assert_eq!(batch.row(i), &single[..], "window {i}");
    }
}

#[test]
fn quant_engine_parity_through_router() {
    // The serving route: precision int8 requests against a real router
    // running real engines over the same model must agree with the f32
    // route at the reply level (≥ 99% over the window set), and carry
    // the cpu-quant target label.
    let shape = ModelShape::default();
    let model = std::sync::Arc::new(decisive_model(shape, 26));
    let router = Router::builder()
        .shape(shape)
        .policy(OffloadPolicy::Static(Target::CpuSingle))
        .max_wait(std::time::Duration::from_millis(1))
        .engine(Box::new(mobirnn::coordinator::CpuQuantEngine::from_f32(&model)))
        .engine(Box::new(mobirnn::coordinator::CpuSingleEngine::new(model)))
        .build()
        .unwrap();
    let ds = har::generate(100, 29);
    let mut agree = 0usize;
    for i in 0..ds.len() {
        let f = router.classify(ds.window(i).to_vec()).unwrap();
        assert_eq!(f.target, "cpu");
        let q = router
            .classify_with(
                ds.window(i).to_vec(),
                ClassifyOptions { precision: Some(Precision::Int8), ..Default::default() },
            )
            .unwrap();
        assert_eq!(q.target, "cpu-quant", "int8 precision must reach the quant engine");
        if f.class == q.class {
            agree += 1;
        }
    }
    assert!(agree >= 99, "serving-level agreement {agree}/100 < 99");
}
