//! Quickstart: load the AOT artifacts, start the serving stack, classify
//! a handful of HAR windows, print what happened.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use std::time::Duration;

use mobirnn::config::Manifest;
use mobirnn::coordinator::{ClassifyOptions, DeviceState, OffloadPolicy, Router};
use mobirnn::har;
use mobirnn::runtime::Runtime;
use mobirnn::simulator::{DeviceProfile, Target};

fn main() -> anyhow::Result<()> {
    // 1. Artifacts: HLO text + MRNW weights + test data, built once by
    //    `make artifacts` (python never runs again after this).
    let manifest = Manifest::load_default()?;
    println!(
        "loaded {} variants; default model {} (test acc {:.1}%)",
        manifest.variants.len(),
        manifest.default_variant,
        100.0 * manifest.train_report.test_accuracy
    );

    // 2. Serving stack via the builder: the standard engine set (PJRT
    //    GPU + native CPU single/multi) behind the utilization-aware
    //    cost-model policy, on a simulated Nexus 5.
    let runtime = Runtime::start(&manifest)?;
    let device = DeviceState::new(DeviceProfile::nexus5());
    let router = Router::builder()
        .policy(OffloadPolicy::CostModel)
        .device(device.clone())
        .max_wait(Duration::from_millis(2))
        .manifest(&manifest, runtime)?
        .build()?;

    // 3. Classify: 8 windows from the artifact test set.
    let ds = har::HarDataset::load(manifest.path(&manifest.har_test.file))?;
    println!("\nidle device — the policy should offload to the GPU:");
    for i in 0..4 {
        let r = router.classify(ds.window(i).to_vec())?;
        println!(
            "  window {i}: {:<18} (gold {:<18}) on {:<9} sim {:.1} ms",
            r.label,
            har::CLASS_NAMES[ds.labels[i] as usize],
            r.target,
            r.sim_ns as f64 / 1e6
        );
    }

    // 4. Load the GPU like a running game — the policy walks off it.
    device.set_gpu_util(0.9);
    device.set_cpu_util(0.9);
    println!("\nGPU at 90% (and CPU at 90%) — §4.5 says: stay on the CPU:");
    for i in 4..8 {
        let r = router.classify(ds.window(i).to_vec())?;
        println!(
            "  window {i}: {:<18} (gold {:<18}) on {:<9} sim {:.1} ms",
            r.label,
            har::CLASS_NAMES[ds.labels[i] as usize],
            r.target,
            r.sim_ns as f64 / 1e6
        );
    }

    // 5. Per-request override: pin one inference to a target regardless
    //    of what the policy would choose.
    device.set_gpu_util(0.0);
    device.set_cpu_util(0.0);
    let pinned = router.classify_with(
        ds.window(0).to_vec(),
        ClassifyOptions { target: Some(Target::CpuSingle), ..Default::default() },
    )?;
    println!(
        "\npinned to cpu (idle device, policy would pick gpu): ran on {:<9} sim {:.1} ms",
        pinned.target,
        pinned.sim_ns as f64 / 1e6
    );

    println!("\nmetrics: {}", router.metrics.to_json().to_json());
    Ok(())
}
