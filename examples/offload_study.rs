//! Offload-policy study: sweep background GPU/CPU load and compare the
//! three policies' *achieved* simulated latency — the paper's §4.5
//! conclusion ("take GPU utilization into account") quantified as a
//! scheduler ablation, plus the adaptive policy's decision trace.
//!
//! ```bash
//! cargo run --release --example offload_study
//! ```

use mobirnn::config::ModelShape;
use mobirnn::coordinator::policy::{LoadSnapshot, OffloadPolicy};
use mobirnn::simulator::{simulate_inference, DeviceProfile, Factorization, Target};

fn main() {
    let profile = DeviceProfile::nexus5();
    let shape = ModelShape::default();
    let policies: Vec<(&str, OffloadPolicy)> = vec![
        ("always-gpu", OffloadPolicy::Static(Target::Gpu(Factorization::Coarse))),
        ("always-cpu-multi", OffloadPolicy::Static(Target::CpuMulti(4))),
        ("always-cpu-1t", OffloadPolicy::Static(Target::CpuSingle)),
        ("threshold:0.6", OffloadPolicy::Threshold { gpu_threshold: 0.6 }),
        ("cost-model", OffloadPolicy::CostModel),
    ];

    println!("simulated Nexus 5, 2l/32h — per-inference latency (ms) by policy\n");
    print!("{:<6}", "util");
    for (name, _) in &policies {
        print!(" {name:>16}");
    }
    println!("  | cost-model picks");

    let mut totals = vec![0.0f64; policies.len()];
    let mut regret_adaptive = 0.0f64;
    let mut regret_static_gpu = 0.0f64;
    for step in 0..=19 {
        let util = step as f64 / 20.0;
        let load = LoadSnapshot { gpu_util: util, cpu_util: util, ..Default::default() };
        print!("{util:<6.2}");
        let mut row = Vec::new();
        for (_, policy) in &policies {
            let target = policy.decide(&profile, shape, 1, load);
            let u = match target {
                Target::Gpu(_) => load.gpu_util,
                _ => load.cpu_util,
            };
            let ms = simulate_inference(&profile, shape, 1, target, u) as f64 / 1e6;
            row.push(ms);
            print!(" {ms:>15.1}");
        }
        for (t, v) in totals.iter_mut().zip(&row) {
            *t += v;
        }
        // Oracle = min over candidate targets at this load.
        let oracle = OffloadPolicy::candidates(&profile)
            .iter()
            .map(|&t| {
                let u = match t {
                    Target::Gpu(_) => load.gpu_util,
                    _ => load.cpu_util,
                };
                simulate_inference(&profile, shape, 1, t, u) as f64 / 1e6
            })
            .fold(f64::INFINITY, f64::min);
        regret_adaptive += row[4] - oracle;
        regret_static_gpu += row[0] - oracle;
        let picked = policies[4].1.decide(&profile, shape, 1, load);
        println!("  | {:?}", picked);
    }

    println!("\nmean latency over the sweep (ms):");
    for ((name, _), total) in policies.iter().zip(&totals) {
        println!("  {name:<18} {:>8.1}", total / 20.0);
    }
    println!("\ncumulative regret vs oracle (ms over 20 load points):");
    println!("  cost-model  {regret_adaptive:>8.1}   (paper's 'utilization-aware' scheduler)");
    println!("  always-gpu  {regret_static_gpu:>8.1}   (what naive offloading pays)");

    assert!(
        regret_adaptive < 0.2 * regret_static_gpu + 1.0,
        "adaptive policy should track the oracle far better than static GPU"
    );
    let best_static = totals[..3].iter().cloned().fold(f64::INFINITY, f64::min);
    assert!(
        totals[4] <= best_static,
        "cost-model ({:.1}) must beat every static policy (best {:.1})",
        totals[4] / 20.0,
        best_static / 20.0
    );
    println!("\nOK: the utilization-aware policy dominates every static choice.");
}
