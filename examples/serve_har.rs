//! End-to-end serving driver (DESIGN.md §5 experiment EE; the repo's
//! "real small workload" validation).
//!
//! Starts the full stack — PJRT runtime, router with cost-model policy,
//! TCP server — then drives the ENTIRE synthetic-HAR test set
//! (paper §4.1: 2947 windows) through it from concurrent TCP clients,
//! under three device-load phases (idle → medium → high), and reports
//! accuracy, throughput, latency percentiles and the offload mix.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_har [-- n_clients]
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use mobirnn::config::Manifest;
use mobirnn::coordinator::{DeviceState, OffloadPolicy, Router};
use mobirnn::har::HarDataset;
use mobirnn::runtime::Runtime;
use mobirnn::server::{Client, Server};
use mobirnn::simulator::DeviceProfile;
use mobirnn::util::Stats;

struct PhaseResult {
    name: &'static str,
    served: usize,
    correct: usize,
    wall: Duration,
    sim_ms: Stats,
    targets: std::collections::BTreeMap<String, usize>,
}

fn run_phase(
    name: &'static str,
    addr: std::net::SocketAddr,
    ds: Arc<HarDataset>,
    range: std::ops::Range<usize>,
    n_clients: usize,
) -> PhaseResult {
    let next = Arc::new(AtomicUsize::new(range.start));
    let end = range.end;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|_| {
            let ds = Arc::clone(&ds);
            let next = Arc::clone(&next);
            std::thread::spawn(move || {
                let mut client = Client::connect(addr).expect("connect");
                let mut correct = 0usize;
                let mut served = 0usize;
                let mut sims = Vec::new();
                let mut targets: std::collections::BTreeMap<String, usize> = Default::default();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= end {
                        break;
                    }
                    let outcome =
                        client.classify(ds.window(i), i as u64).expect("classify");
                    served += 1;
                    if outcome.class == ds.labels[i] as usize {
                        correct += 1;
                    }
                    sims.push(outcome.sim_latency_us / 1e3);
                    *targets.entry(outcome.target).or_default() += 1;
                }
                (served, correct, sims, targets)
            })
        })
        .collect();
    let mut served = 0;
    let mut correct = 0;
    let mut sim_ms = Stats::new();
    let mut targets: std::collections::BTreeMap<String, usize> = Default::default();
    for h in handles {
        let (s, c, sims, tg) = h.join().expect("client thread");
        served += s;
        correct += c;
        for v in sims {
            sim_ms.push(v);
        }
        for (k, v) in tg {
            *targets.entry(k).or_default() += v;
        }
    }
    PhaseResult { name, served, correct, wall: t0.elapsed(), sim_ms, targets }
}

fn print_phase(r: &PhaseResult) {
    println!(
        "\n--- phase: {} ({} windows, {} clients-shared) ---",
        r.name,
        r.served,
        r.targets.values().sum::<usize>()
    );
    println!(
        "accuracy   : {}/{} = {:.1}%",
        r.correct,
        r.served,
        100.0 * r.correct as f64 / r.served.max(1) as f64
    );
    println!(
        "throughput : {:.0} inferences/s (host wall {:.2}s)",
        r.served as f64 / r.wall.as_secs_f64(),
        r.wall.as_secs_f64()
    );
    println!(
        "sim latency: mean {:.1} ms  p50 {:.1}  p95 {:.1}  p99 {:.1}  max {:.1}",
        r.sim_ms.mean(),
        r.sim_ms.percentile(50.0),
        r.sim_ms.percentile(95.0),
        r.sim_ms.percentile(99.0),
        r.sim_ms.max()
    );
    println!("offload mix: {:?}", r.targets);
}

fn main() -> anyhow::Result<()> {
    let n_clients: usize = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(4);

    let manifest = Manifest::load_default()?;
    let runtime = Runtime::start(&manifest)?;
    let device = DeviceState::new(DeviceProfile::nexus5());
    let router = Router::builder()
        .policy(OffloadPolicy::CostModel)
        .device(device.clone())
        .max_wait(Duration::from_millis(2))
        .manifest(&manifest, runtime)?
        .build()?;
    let metrics = Arc::clone(&router.metrics);
    let server = Server::bind("127.0.0.1:0", router)?;
    let addr = server.addr();
    println!(
        "serving {} on {addr} — driving the full {}-window HAR test set with {n_clients} clients",
        manifest.default_variant, manifest.har_test.n
    );

    let ds = Arc::new(HarDataset::load(manifest.path(&manifest.har_test.file))?);
    let n = ds.len();
    let third = n / 3;

    // Phase 1: idle device — everything should offload to the GPU.
    let p1 = run_phase("idle device", addr, Arc::clone(&ds), 0..third, n_clients);
    print_phase(&p1);

    // Phase 2: medium GPU load (a map app animating, say).
    let mut c = Client::connect(addr)?;
    c.set_load(0.4, 0.4)?;
    let p2 = run_phase("medium load (40%)", addr, Arc::clone(&ds), third..2 * third, n_clients);
    print_phase(&p2);

    // Phase 3: high load (a game) — §4.5 says: get off the GPU. Driven by
    // a SINGLE client so batches stay at 1, the paper's own setting: with
    // deep batches the cost model keeps choosing the GPU even under load,
    // because one launch sequence amortizes over the whole batch — an
    // effect the paper's unbatched runtime could not exploit.
    c.set_load(0.85, 0.85)?;
    let p3 = run_phase("high load (85%), unbatched", addr, Arc::clone(&ds), 2 * third..n, 1);
    print_phase(&p3);

    // Summary + assertions of the paper's qualitative behaviour.
    let total_correct = p1.correct + p2.correct + p3.correct;
    let total = p1.served + p2.served + p3.served;
    println!("\n=== serve_har summary ===");
    println!(
        "served {total} windows end-to-end over TCP; accuracy {:.1}% (train report: {:.1}%)",
        100.0 * total_correct as f64 / total as f64,
        100.0 * manifest.train_report.test_accuracy
    );
    println!("server metrics: {}", metrics.to_json().to_json());

    assert!(p1.targets.keys().all(|t| t == "gpu"), "idle phase must offload: {:?}", p1.targets);
    assert!(
        p3.targets.keys().all(|t| t != "gpu"),
        "high-load phase must avoid the GPU: {:?}",
        p3.targets
    );
    assert!(p3.sim_ms.mean() > p1.sim_ms.mean(), "load must cost simulated latency");
    let acc = total_correct as f64 / total as f64;
    assert!(acc > 0.7, "end-to-end accuracy {acc} too far below the train report");
    println!("\nOK: offload mix followed §4.5 and accuracy held end-to-end.");
    Ok(())
}
