//! Factorization visualizer: Fig 2 as ASCII, plus the §3.2/§3.3
//! optimization ablation table on the simulated Nexus 5.
//!
//! ```bash
//! cargo run --release --example factorization_viz
//! ```

use mobirnn::config::ModelShape;
use mobirnn::simulator::{
    build_trace_with_slots, gpu_run, simulate_gpu_with_opts, DeviceProfile, Factorization,
    TraceOpts,
};

fn main() {
    let profile = DeviceProfile::nexus5();

    // ---- Fig 2: the paper's 32-dim x (32x120) gate GEMM --------------
    // One row per work unit; '#' marks the columns it computes.
    println!("Fig 2 — factorizing 120 vector products (32-dim each), GPU has 12 slots\n");
    println!("(b) CUDA-style fine factorization: 120 units, 120 function calls");
    println!("    unit 000: #       (1 product per unit, 12 run at a time, 10 waves)");
    println!("    unit 001:  #");
    println!("    ...       (118 more single-product units; every call pays dispatch)");
    println!();
    println!("(c) RenderScript coarse packing: 12 units x 10 products, ONE call");
    for unit in 0..12 {
        let start = unit * 10;
        let mut row = String::new();
        for col in 0..120 {
            row.push(if (start..start + 10).contains(&col) { '#' } else { '.' });
        }
        println!("    unit {unit:03}: {row}");
    }

    let shape = ModelShape { num_layers: 1, hidden: 30, input_dim: 2, seq_len: 1, num_classes: 6 };
    println!("\nsimulated cost of that single GEMM on the Adreno-330 stand-in:");
    for (name, fact) in
        [("fine", Factorization::Fine), ("coarse", Factorization::Coarse)]
    {
        let trace = build_trace_with_slots(shape, 1, fact, &TraceOpts::mobirnn(), profile.gpu_slots);
        let r = gpu_run(&profile, &trace, 0.0, 0);
        println!(
            "  {name:<7} {:>4} launches  dispatch {:>7.1}µs  compute {:>7.1}µs  total {:>7.1}µs",
            r.num_launches,
            r.dispatch_ns as f64 / 1e3,
            (r.compute_ns + r.mem_stall_ns) as f64 / 1e3,
            r.total_ns as f64 / 1e3
        );
    }

    // ---- §3.2/3.3 ablations on the full default model ----------------
    println!("\nOptimization ablations, full 2l/32h inference (simulated Nexus 5):\n");
    let base = TraceOpts::mobirnn();
    let cases: Vec<(&str, TraceOpts)> = vec![
        ("MobiRNN (all opts)", base),
        ("- combined GEMM", TraceOpts { combined_gemm: false, ..base }),
        ("- fused point-wise", TraceOpts { fused_pointwise: false, ..base }),
        ("- memory pool", TraceOpts { mem_pool: false, ..base }),
        ("- divergence-free", TraceOpts { divergence_free: false, ..base }),
        ("naive port (none)", TraceOpts::naive()),
    ];
    let shape = ModelShape::default();
    let mobirnn_ns =
        simulate_gpu_with_opts(&profile, shape, 1, Factorization::Coarse, &base, 0.0);
    println!("{:<22} {:>10} {:>10}", "configuration", "ms/infer", "vs MobiRNN");
    for (name, opts) in &cases {
        let ns = simulate_gpu_with_opts(&profile, shape, 1, Factorization::Coarse, opts, 0.0);
        println!(
            "{name:<22} {:>10.1} {:>9.2}x",
            ns as f64 / 1e6,
            ns as f64 / mobirnn_ns as f64
        );
    }
    println!(
        "\n(and the CUDA-style fine factorization with all opts on: {:.1} ms — the\n\
         packing decision dominates everything else, which is the paper's point)",
        simulate_gpu_with_opts(&profile, shape, 1, Factorization::Fine, &base, 0.0) as f64 / 1e6
    );
}
